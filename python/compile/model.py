"""L2 — Llama-3-style decoder whose attention layers call the L1 kernels.

This is the analogue of vLLM's model runner: the "simple" layers (RMSNorm,
projections, RoPE, SwiGLU) are plain JAX — lowered and fused by XLA the way
vLLM lowers them with torch.compile — while the performance-critical
attention layer is the Pallas paged-attention kernel selected by the
compile-time :class:`KernelConfig`.

One jitted ``model_step`` handles both prefill and decode: the phase is
purely a property of the batch metadata (query lengths), exactly as in
vLLM v1. Sampling is greedy and happens in-graph so the serving hot path
never ships logits across PJRT.

KV-cache convention shared with the Rust coordinator:
  * the whole mutable state is ONE f32 array
    ``[num_layers, 2, num_slots, num_kv_heads, head_size]`` (k=index 0,
    v=index 1); physical page ``b`` owns slots
    ``[b*block_size, (b+1)*block_size)``;
  * physical page 0 is reserved as a scratch page — padded ``slot_mapping``
    entries point into it so masked lanes scatter harmlessly, and the
    sampled tokens are stashed in its V region for the extract executable;
  * the executable returns the updated state; Rust chains it as a
    device-resident PJRT buffer between steps (no host round-trip).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import Bucket, KernelConfig, ModelConfig
from .kernels import get_kernel


class Params(NamedTuple):
    """Model weights; per-layer tensors are stacked on a leading layer axis
    so the layer loop lowers to one ``scan`` body (compact HLO) and the
    weight file has a fixed tensor count regardless of depth."""

    embed: jax.Array        # [vocab, hidden]
    attn_norm: jax.Array    # [layers, hidden]
    wq: jax.Array           # [layers, hidden, q_heads*head]
    wk: jax.Array           # [layers, hidden, kv_heads*head]
    wv: jax.Array           # [layers, hidden, kv_heads*head]
    wo: jax.Array           # [layers, q_heads*head, hidden]
    mlp_norm: jax.Array     # [layers, hidden]
    w_gate: jax.Array       # [layers, hidden, intermediate]
    w_up: jax.Array         # [layers, hidden, intermediate]
    w_down: jax.Array       # [layers, intermediate, hidden]
    final_norm: jax.Array   # [hidden]
    lm_head: jax.Array      # [hidden, vocab]


def init_params(model: ModelConfig, seed: int = 0) -> Params:
    """Random weights with 1/sqrt(fan_in) scaling (numerically tame logits;
    attention cost does not depend on weight values — DESIGN.md §5)."""
    rng = np.random.default_rng(seed)
    L, H = model.num_layers, model.hidden_size
    I, V = model.intermediate_size, model.vocab_size
    QS, KS = model.q_size, model.kv_size

    def w(*shape):
        fan_in = shape[-2] if len(shape) > 1 else shape[-1]
        return (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)

    p = Params(
        embed=w(V, H),
        attn_norm=np.ones((L, H), np.float32),
        wq=w(L, H, QS), wk=w(L, H, KS), wv=w(L, H, KS), wo=w(L, QS, H),
        mlp_norm=np.ones((L, H), np.float32),
        w_gate=w(L, H, I), w_up=w(L, H, I), w_down=w(L, I, H),
        final_norm=np.ones((H,), np.float32),
        lm_head=w(H, V),
    )
    return Params(*(jnp.asarray(t) for t in p))


def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, [tokens, heads, head] with absolute positions."""
    head = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, head // 2, dtype=jnp.float32)
                      / (head // 2))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(angles)[:, None, :]     # [tokens, 1, head/2]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., : head // 2], x[..., head // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def model_step(
    params: Params,
    token_ids: jax.Array,        # [max_tokens] i32
    positions: jax.Array,        # [max_tokens] i32 (ctx + local)
    kv_caches: jax.Array,        # [layers, 2, slots, kv_heads, head]
    block_table: jax.Array,      # [max_seqs, max_blocks] i32
    seq_lens: jax.Array,         # [max_seqs] i32
    ctx_lens: jax.Array,         # [max_seqs] i32
    query_start_loc: jax.Array,  # [max_seqs+1] i32 (block_q aligned)
    slot_mapping: jax.Array,     # [max_tokens] i32 (padding → scratch page 0)
    last_token_idx: jax.Array,   # [max_seqs] i32 (packed row of last token)
    *,
    cfg: KernelConfig,
    model: ModelConfig,
    bucket: Bucket,
):
    """One serving step. Returns (next_tokens [max_seqs], kv_caches).

    K and V caches are interleaved per layer (``kv_caches[l, 0]`` = keys,
    ``kv_caches[l, 1]`` = values) so the whole mutable state is ONE array.
    The layer loop is *unrolled* rather than ``scan``-ed: chained scatters
    on one buffer let XLA's copy elision update the state nearly in place,
    where scan's per-layer slice/stack forced two layer-sized copies per
    layer — a 1.45x step-time win (EXPERIMENTS.md §Perf P6). Without PJRT
    buffer donation one state-sized copy per step is the floor.
    """
    kernel = get_kernel(cfg)
    H, D = model.num_q_heads, model.head_size
    KV = model.num_kv_heads

    x = params.embed[token_ids]            # [tokens, hidden]
    kv = kv_caches

    for l in range(model.num_layers):
        # --- attention ---
        h = rms_norm(x, params.attn_norm[l])
        q = (h @ params.wq[l]).reshape(-1, H, D)
        k = (h @ params.wk[l]).reshape(-1, KV, D)
        v = (h @ params.wv[l]).reshape(-1, KV, D)
        q = rope(q, positions, model.rope_theta)
        k = rope(k, positions, model.rope_theta)
        # reshape_and_cache: write new K/V into the paged cache first, then
        # attend against the cache (vLLM ordering — queries see their own
        # keys through the cache).
        kv = kv.at[l, 0, slot_mapping].set(k)
        kv = kv.at[l, 1, slot_mapping].set(v)
        attn = kernel(q, kv[l, 0], kv[l, 1], block_table, seq_lens,
                      ctx_lens, query_start_loc, cfg=cfg, model=model,
                      bucket=bucket)
        x = x + attn.reshape(-1, H * D) @ params.wo[l]
        # --- mlp (SwiGLU) ---
        h = rms_norm(x, params.mlp_norm[l])
        x = x + (jax.nn.silu(h @ params.w_gate[l])
                 * (h @ params.w_up[l])) @ params.w_down[l]
    kv_caches = kv

    x = rms_norm(x, params.final_norm)
    last = x[last_token_idx]               # [max_seqs, hidden]
    logits = last @ params.lm_head
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, kv_caches


#: Number of sampled-token floats stashed into the state (must exceed
#: every bucket's max_seqs so state is interchangeable across all of a
#: model's executables). The stash lives inside layer 0's V-cache scratch
#: page (physical page 0, never read by kernels), so the state needs no
#: extra tail and the step avoids a concatenate copy (§Perf P6).
SAMPLE_PAD = 64


def cache_elements(model: ModelConfig, num_slots: int) -> int:
    return (model.num_layers * num_slots * model.num_kv_heads
            * model.head_size)


def state_len(model: ModelConfig, num_slots: int) -> int:
    return 2 * cache_elements(model, num_slots)


def stash_offset(model: ModelConfig, num_slots: int) -> int:
    """Flat-state offset of (layer 0, V-cache, slot 0): the token stash."""
    return num_slots * model.num_kv_heads * model.head_size


def model_step_flat(
    params: Params,
    token_ids, positions, state, block_table, seq_lens, ctx_lens,
    query_start_loc, slot_mapping, last_token_idx,
    *, cfg: KernelConfig, model: ModelConfig, bucket: Bucket,
):
    """Single-output wrapper around :func:`model_step`.

    The PJRT C wrapper (xla_extension 0.5.1) returns multi-result
    executables as ONE tuple buffer that can only be decomposed via a full
    host copy, and buffer donation is not exposed. To keep the KV cache
    device-resident across steps, the whole mutable state travels as one
    flat f32 array that the Rust engine feeds straight back into the next
    step (`execute_b` chaining). Sampled tokens are stashed inside the
    scratch page (kernels never read physical page 0) and recovered by a
    tiny separate *extract* executable — no concatenate, so the step pays
    only the scan's single state-sized copy (§Perf P6).
    """
    L, KV, D = model.num_layers, model.num_kv_heads, model.head_size
    assert SAMPLE_PAD <= cfg.block_size * KV * D, "stash must fit page 0"
    kv_caches = state.reshape(L, 2, bucket.num_slots, KV, D)
    next_tokens, kv_caches = model_step(
        params, token_ids, positions, kv_caches, block_table,
        seq_lens, ctx_lens, query_start_loc, slot_mapping, last_token_idx,
        cfg=cfg, model=model, bucket=bucket)
    flat = kv_caches.reshape(-1)
    stash = jnp.zeros((SAMPLE_PAD,), jnp.float32)
    stash = stash.at[: bucket.max_seqs].set(next_tokens.astype(jnp.float32))
    off = stash_offset(model, bucket.num_slots)
    return jax.lax.dynamic_update_slice(flat, stash, (off,))


def extract_tokens(state, *, model: ModelConfig, num_slots: int):
    """The extract executable: the sampled-token stash in the scratch page."""
    off = stash_offset(model, num_slots)
    return jax.lax.dynamic_slice(state, (off,), (SAMPLE_PAD,))


def make_model_fn(cfg: KernelConfig, model: ModelConfig, bucket: Bucket):
    """Positional-only closure for AOT lowering: params tensors first (in
    Params field order), then the step operands (order documented in the
    manifest and mirrored by rust/src/runtime)."""

    def fn(*ops):
        params = Params(*ops[: len(Params._fields)])
        rest = ops[len(Params._fields):]
        return model_step_flat(params, *rest, cfg=cfg, model=model,
                               bucket=bucket)

    return fn


def model_step_signature(model: ModelConfig, bucket: Bucket):
    """(name, shape, dtype) list of the non-param operands."""
    f32, i32 = jnp.float32, jnp.int32
    return [
        ("token_ids", (bucket.max_tokens,), i32),
        ("positions", (bucket.max_tokens,), i32),
        ("state", (state_len(model, bucket.num_slots),), f32),
        ("block_table", (bucket.max_seqs, bucket.max_blocks), i32),
        ("seq_lens", (bucket.max_seqs,), i32),
        ("ctx_lens", (bucket.max_seqs,), i32),
        ("query_start_loc", (bucket.max_seqs + 1,), i32),
        ("slot_mapping", (bucket.max_tokens,), i32),
        ("last_token_idx", (bucket.max_seqs,), i32),
    ]


def params_signature(model: ModelConfig):
    p = init_params(ModelConfig(**{**model.to_json()}))  # shapes only
    return [(name, tuple(np.asarray(getattr(p, name)).shape), jnp.float32)
            for name in Params._fields]
