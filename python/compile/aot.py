"""AOT export: lower L2/L1 to HLO **text** artifacts + weights + manifest.

Python runs exactly once, at build time (``make artifacts``); the Rust
coordinator then loads ``artifacts/*.hlo.txt`` through
``HloModuleProto::from_text_file`` and never touches Python again.

HLO *text* — not ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
xla_extension 0.5.1 (the version behind the published ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Each artifact freezes one (kernel config, bucket) pair — the AOT analogue
of one recorded CUDA/HIP graph (§6.2): vLLM records one graph per
power-of-two batch size; we compile one executable per power-of-two
bucket, and the Rust heuristics (§5) choose among them with zero JIT cost.

Profiles:
  default  tiny-model step executables (all variants) + a small kernel set
           — what tests, examples/quickstart and cargo test use.
  bench    kernel-only executables over the Fig. 6/7/8 sweep grid
           (Llama-3-8B-like head geometry, scaled).
  e2e      small-model step executables for Fig. 9 / examples/serving.
  100m     ~100M-parameter model for the heavy end-to-end run.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import Bucket, KernelConfig, ModelConfig, decode_bucket
from .kernels import get_kernel
from .kernels.common import kernel_signature
from .model import (Params, SAMPLE_PAD, extract_tokens, init_params,
                    make_model_fn, model_step_signature, state_len)

# ---------------------------------------------------------------- model zoo

MODELS: dict[str, ModelConfig] = {
    # CI / quickstart: small enough that every variant exports in seconds.
    "tiny": ModelConfig(num_layers=2, hidden_size=256, num_q_heads=8,
                        num_kv_heads=2, head_size=32, intermediate_size=512,
                        vocab_size=2048, max_model_len=512),
    # Fig. 9 / serving example: Llama-like head geometry, 4 layers.
    "small": ModelConfig(num_layers=4, hidden_size=512, num_q_heads=8,
                         num_kv_heads=2, head_size=64,
                         intermediate_size=1024, vocab_size=4096,
                         max_model_len=1024),
    # ~100M parameters for the headline end-to-end validation.
    "llama100m": ModelConfig(num_layers=10, hidden_size=768, num_q_heads=12,
                             num_kv_heads=4, head_size=64,
                             intermediate_size=2048, vocab_size=8192,
                             max_model_len=1024),
}

#: Geometry of the kernel-only microbench artifacts. The paper bases its
#: microbenchmarks on Llama-3-8B (128 head size, 32 Q heads, 8 KV heads);
#: we scale to 64/8/2 — same queries_per_kv=4 GQA ratio — per DESIGN.md §5.
KERNEL_GEOM = ModelConfig(num_layers=1, hidden_size=512, num_q_heads=8,
                          num_kv_heads=2, head_size=64,
                          intermediate_size=1024, vocab_size=1024,
                          max_model_len=4096)


def dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every artifact is built to return exactly ONE
    # array (see model_step_flat) so PJRT hands back a plain buffer that
    # can be chained into the next execute without a host round-trip.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


# ------------------------------------------------------------- artifact set


@dataclasses.dataclass
class Artifact:
    kind: str                  # "kernel" | "model"
    name: str
    fn: object                 # callable to lower
    inputs: list               # [(name, shape, dtype)]
    outputs: list
    cfg: KernelConfig
    bucket: Bucket
    model_name: str | None = None

    def manifest_entry(self, path: str) -> dict:
        return {
            "kind": self.kind,
            "name": self.name,
            "path": path,
            "variant": self.cfg.variant,
            "config": self.cfg.to_json(),
            "bucket": self.bucket.to_json(),
            "model": self.model_name,
            "inputs": [
                {"name": n, "shape": list(s), "dtype": dtype_str(d)}
                for n, s, d in self.inputs
            ],
            "outputs": [
                {"name": n, "shape": list(s), "dtype": dtype_str(d)}
                for n, s, d in self.outputs
            ],
        }


def kernel_artifact(cfg: KernelConfig, bucket: Bucket,
                    geom: ModelConfig = KERNEL_GEOM) -> Artifact:
    kern = get_kernel(cfg)

    def fn(*ops):
        return kern(*ops, cfg=cfg, model=geom, bucket=bucket)

    sig = kernel_signature(bucket, geom)
    out_sig = [("out", (bucket.max_tokens, geom.num_q_heads, geom.head_size),
                jnp.float32)]
    name = f"kernel-{cfg.tag()}-{bucket.tag()}"
    return Artifact("kernel", name, fn, sig, out_sig, cfg, bucket)


def model_artifact(model_name: str, cfg: KernelConfig, bucket: Bucket,
                   params_sig: list) -> Artifact:
    model = MODELS[model_name]
    fn = make_model_fn(cfg, model, bucket)
    sig = params_sig + model_step_signature(model, bucket)
    out_sig = [("state", (state_len(model, bucket.num_slots),), jnp.float32)]
    name = f"model-{model_name}-{cfg.tag()}-{bucket.tag()}"
    return Artifact("model", name, fn, sig, out_sig, cfg, bucket, model_name)


def extract_artifact(model_name: str, num_slots: int,
                     any_cfg: KernelConfig, any_bucket: Bucket) -> Artifact:
    """Tiny executable reading the sampled-token tail out of the flat state
    (CopyRawToHost is unimplemented in xla_extension 0.5.1, so the partial
    read is itself a compiled computation — one extra 'kernel launch' per
    step, the same launch-overhead trade-off the paper dissects in §6.2)."""
    model = MODELS[model_name]

    def fn(state):
        return extract_tokens(state, model=model, num_slots=num_slots)

    sig = [("state", (state_len(model, num_slots),), jnp.float32)]
    out_sig = [("tokens", (SAMPLE_PAD,), jnp.float32)]
    name = f"extract-{model_name}"
    return Artifact("extract", name, fn, sig, out_sig, any_cfg, any_bucket,
                    model_name)


# --------------------------------------------------------------- weights IO


def write_weights(params: Params, path: str) -> list[dict]:
    """Raw little-endian f32 concatenation + per-tensor index (the manifest
    carries offsets so Rust mmap/reads it without a numpy dependency)."""
    index = []
    offset = 0
    with open(path, "wb") as f:
        for name in Params._fields:
            arr = np.ascontiguousarray(np.asarray(getattr(params, name)),
                                       dtype=np.float32)
            data = arr.tobytes()
            index.append({"name": name, "shape": list(arr.shape),
                          "dtype": "f32", "offset": offset,
                          "nbytes": len(data)})
            f.write(data)
            offset += len(data)
    return index


# ----------------------------------------------------------------- profiles


def model_buckets(model: ModelConfig, block_size: int,
                  decode_seqs: list[int], prefill: list[tuple[int, int]],
                  cache_seqs: int) -> list[Bucket]:
    """Bucket set for one model: shared cache sizing, power-of-two shapes."""
    max_blocks = model.max_model_len // block_size
    # +1 page: physical page 0 is the scratch page for padded slots.
    num_slots = (cache_seqs * max_blocks + 1) * block_size
    out = [decode_bucket(s, max_blocks=max_blocks, num_slots=num_slots)
           for s in decode_seqs]
    out += [Bucket(max_seqs=s, max_tokens=t, max_blocks=max_blocks,
                   num_slots=num_slots) for s, t in prefill]
    return out


def profile_default() -> tuple[list[Artifact], list[str]]:
    arts: list[Artifact] = []
    models = ["tiny"]
    model = MODELS["tiny"]
    params_sig = _params_sig(model)
    buckets = model_buckets(model, 16, decode_seqs=[4],
                            prefill=[(4, 64)], cache_seqs=4)
    dec, pre = buckets[0], buckets[1]
    # use_dot=False throughout: on the XLA-CPU substrate tiny-tile GEMM
    # dispatch overhead inverts the paper's §8 tl.dot recommendation; the
    # bench profile exports dot variants for the ablation (EXPERIMENTS.md).
    for variant, bucket, kw in [
        ("naive", dec, dict(block_q=1)),
        ("qblock", dec, dict(block_q=1)),
        ("parts", dec, dict(block_q=1, num_segments=4)),
        ("static", dec, dict(block_q=1, static_programs=4)),
        ("flash", dec, dict(block_q=1)),
        ("naive", pre, dict(block_q=1)),
        ("qblock", pre, dict(block_q=4)),
        ("static", pre, dict(block_q=4, static_programs=8)),
        ("flash", pre, dict(block_q=4)),
    ]:
        cfg = KernelConfig(variant=variant, block_size=16, tile_n=16,
                           use_dot=False, **kw)
        arts.append(model_artifact("tiny", cfg, bucket, params_sig))
    arts.append(extract_artifact("tiny", dec.num_slots,
                                 arts[0].cfg, dec))
    # small kernel-only set so `repro bench-micro`, `repro tune` and the
    # quick-mode figure benches work out of the box
    slots = (4 * 32 + 1) * 16                     # seqlens up to 512
    kb = decode_bucket(4, max_blocks=32, num_slots=slots)
    for variant, kw in [("naive", {}), ("qblock", {}),
                        ("parts", dict(num_segments=4)), ("flash", {}),
                        ("static", dict(static_programs=4))]:
        cfg = KernelConfig(variant=variant, block_size=16, tile_n=16,
                           block_q=1, use_dot=False, **kw)
        arts.append(kernel_artifact(cfg, kb))
    # the tl.dot ablation pair (§8): same qblock config, MMA path
    arts.append(kernel_artifact(KernelConfig(
        variant="qblock", block_size=16, tile_n=16, block_q=1), kb))
    # flex-tile decode variants (quick Fig. 7)
    for tn in (32, 64):
        arts.append(kernel_artifact(KernelConfig(
            variant="qblock", block_size=16, tile_n=tn, block_q=1,
            use_dot=False), kb))
        arts.append(kernel_artifact(KernelConfig(
            variant="parts", block_size=16, tile_n=tn, block_q=1,
            num_segments=4, use_dot=False), kb))
    # mixed/prefill bucket (quick Fig. 6c/8)
    mb = Bucket(max_seqs=4, max_tokens=128, max_blocks=32, num_slots=slots)
    for variant, kw in [("naive", dict(block_q=1)),
                        ("qblock", dict(block_q=4)),
                        ("qblock", dict(block_q=16)),
                        ("static", dict(block_q=4, static_programs=8)),
                        ("flash", dict(block_q=4))]:
        cfg = KernelConfig(variant=variant, block_size=16, tile_n=16,
                           use_dot=False, **kw)
        arts.append(kernel_artifact(cfg, mb))
    for tn in (32, 64):
        arts.append(kernel_artifact(KernelConfig(
            variant="qblock", block_size=16, tile_n=tn, block_q=4,
            use_dot=False), mb))
    return arts, models


def profile_bench() -> tuple[list[Artifact], list[str]]:
    """Fig. 6/7/8 kernel grid: variants × tile sizes × buckets."""
    arts: list[Artifact] = []
    bs = 16
    max_blocks = 2048 // bs                       # seqlens up to 2048
    slots = (8 * max_blocks + 1) * bs

    dec_buckets = [decode_bucket(s, max_blocks=max_blocks, num_slots=slots)
                   for s in (1, 2, 4, 8)]
    mix_buckets = [Bucket(max_seqs=8, max_tokens=t, max_blocks=max_blocks,
                          num_slots=slots) for t in (128, 512)]

    for b in dec_buckets:
        arts.append(kernel_artifact(KernelConfig(
            variant="naive", block_size=bs, tile_n=bs, block_q=1,
            use_dot=False), b))
        arts.append(kernel_artifact(KernelConfig(
            variant="qblock", block_size=bs, tile_n=bs, block_q=1,
            use_dot=False), b))
        # the §8 tl.dot ablation pair
        arts.append(kernel_artifact(KernelConfig(
            variant="qblock", block_size=bs, tile_n=bs, block_q=1), b))
        arts.append(kernel_artifact(KernelConfig(
            variant="static", block_size=bs, tile_n=bs, block_q=1,
            static_programs=16, use_dot=False), b))
        for tn in (16, 32, 64):                    # §4.6 adjustable tiles
            for nseg in (4, 8):
                arts.append(kernel_artifact(KernelConfig(
                    variant="parts", block_size=bs, tile_n=tn, block_q=1,
                    num_segments=nseg, use_dot=False), b))
            if tn != bs:
                arts.append(kernel_artifact(KernelConfig(
                    variant="qblock", block_size=bs, tile_n=tn,
                    block_q=1, use_dot=False), b))
        arts.append(kernel_artifact(KernelConfig(
            variant="flash", block_size=bs, tile_n=bs, block_q=1,
            use_dot=False), b))
    for b in mix_buckets:
        arts.append(kernel_artifact(KernelConfig(
            variant="naive", block_size=bs, tile_n=bs, block_q=1,
            use_dot=False), b))
        for bq in (4, 16):
            for tn in (16, 32, 64):
                arts.append(kernel_artifact(KernelConfig(
                    variant="qblock", block_size=bs, tile_n=tn,
                    block_q=bq, use_dot=False), b))
            arts.append(kernel_artifact(KernelConfig(
                variant="static", block_size=bs, tile_n=32, block_q=bq,
                static_programs=16, use_dot=False), b))
        arts.append(kernel_artifact(KernelConfig(
            variant="flash", block_size=bs, tile_n=bs, block_q=4,
            use_dot=False), b))
    return arts, []


def profile_e2e() -> tuple[list[Artifact], list[str]]:
    """Fig. 9 / serving: small model, decode + prefill buckets, all variants."""
    arts: list[Artifact] = []
    model = MODELS["small"]
    params_sig = _params_sig(model)
    buckets = model_buckets(model, 16, decode_seqs=[1, 2, 4],
                            prefill=[(1, 128), (2, 128), (4, 256)],
                            cache_seqs=4)
    dec_b, pre_b = buckets[:3], buckets[3:]
    for b in dec_b:
        for variant, kw in [
            ("naive", dict(tile_n=16)),
            ("qblock", {}),
            ("parts", dict(num_segments=8)),
            ("static", dict(static_programs=8)),
            ("flash", {}),
        ]:
            cfg = KernelConfig(**{**dict(variant=variant, block_size=16,
                                         tile_n=32, block_q=1,
                                         use_dot=False), **kw})
            arts.append(model_artifact("small", cfg, b, params_sig))
    for b in pre_b:
        for variant, kw in [
            ("naive", dict(block_q=1, tile_n=16)),
            ("qblock", dict(block_q=16)),
            ("static", dict(block_q=16, static_programs=8)),
            ("flash", dict(block_q=16)),
        ]:
            cfg = KernelConfig(**{**dict(variant=variant, block_size=16,
                                         tile_n=32, use_dot=False), **kw})
            arts.append(model_artifact("small", cfg, b, params_sig))
    arts.append(extract_artifact("small", dec_b[0].num_slots,
                                 arts[0].cfg, dec_b[0]))
    return arts, ["small"]


def profile_100m() -> tuple[list[Artifact], list[str]]:
    arts: list[Artifact] = []
    model = MODELS["llama100m"]
    params_sig = _params_sig(model)
    buckets = model_buckets(model, 16, decode_seqs=[2, 4],
                            prefill=[(2, 128), (4, 256)], cache_seqs=4)
    for b in buckets:
        bq = 1 if b.max_tokens == b.max_seqs else 16
        cfg = KernelConfig(variant="static", block_size=16, tile_n=32,
                           block_q=bq, static_programs=8, use_dot=False)
        arts.append(model_artifact("llama100m", cfg, b, params_sig))
    arts.append(extract_artifact("llama100m", buckets[0].num_slots,
                                 arts[0].cfg, buckets[0]))
    return arts, ["llama100m"]


PROFILES = {
    "default": profile_default,
    "bench": profile_bench,
    "e2e": profile_e2e,
    "100m": profile_100m,
}


def _params_sig(model: ModelConfig) -> list:
    L, H = model.num_layers, model.hidden_size
    I, V = model.intermediate_size, model.vocab_size
    QS, KS = model.q_size, model.kv_size
    f32 = jnp.float32
    return [
        ("embed", (V, H), f32),
        ("attn_norm", (L, H), f32),
        ("wq", (L, H, QS), f32), ("wk", (L, H, KS), f32),
        ("wv", (L, H, KS), f32), ("wo", (L, QS, H), f32),
        ("mlp_norm", (L, H), f32),
        ("w_gate", (L, H, I), f32), ("w_up", (L, H, I), f32),
        ("w_down", (L, I, H), f32),
        ("final_norm", (H,), f32),
        ("lm_head", (H, V), f32),
    ]


# -------------------------------------------------------------------- main


def export(out_dir: str, profile: str, *, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    arts, weight_models = PROFILES[profile]()

    manifest = {
        "version": 1,
        "profile": profile,
        "kernel_geom": KERNEL_GEOM.to_json(),
        "models": {},
        "artifacts": [],
    }

    for mname in weight_models:
        model = MODELS[mname]
        params = init_params(model, seed=1234)
        wpath = f"weights-{mname}.bin"
        index = write_weights(params, os.path.join(out_dir, wpath))
        manifest["models"][mname] = {
            "config": model.to_json(),
            "weights_path": wpath,
            "tensors": index,
        }
        if verbose:
            total = sum(t["nbytes"] for t in index)
            print(f"[aot] weights {mname}: {total / 1e6:.1f} MB "
                  f"({model.param_count() / 1e6:.1f}M params)")

    for art in arts:
        t0 = time.time()
        specs = [jax.ShapeDtypeStruct(s, d) for _, s, d in art.inputs]
        lowered = jax.jit(art.fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{art.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(art.manifest_entry(fname))
        if verbose:
            print(f"[aot] {fname}: {len(text) / 1e6:.2f} MB "
                  f"({time.time() - t0:.1f}s)")

    mpath = os.path.join(out_dir, f"manifest-{profile}.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] wrote {mpath} ({len(arts)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="default",
                    choices=[*PROFILES.keys(), "all"])
    args = ap.parse_args()
    profiles = list(PROFILES) if args.profile == "all" else [args.profile]
    for p in profiles:
        export(args.out_dir, p)


if __name__ == "__main__":
    main()
