"""Analytic kernel performance model — the TPU-facing targets of DESIGN §7.

Interpret-mode wallclock on XLA-CPU cannot expose parallelism effects
(grid cells execute sequentially), so the structural quantities the paper
optimizes for on GPUs are computed analytically per kernel config:

  * per-instance VMEM footprint — the tile working set that must fit the
    TPU's ~16 MiB VMEM (the analogue of Triton's shared-memory budget),
  * MXU-eligible FLOP fraction — how much of the arithmetic runs on the
    systolic array (`jnp.dot`) vs. the VPU (elementwise path),
  * program-instance count and per-instance critical path (serial tile
    iterations) — the occupancy/wave model behind §4.5's parallel tiled
    softmax and §6.2's excess-instance discussion,
  * bytes moved per instance and arithmetic intensity.

``python -m compile.analysis`` prints the model for every config the AOT
profiles export; pytest pins the qualitative claims (naive has 4x the
loads of qblock, parts divides the critical path by the segment count,
everything fits VMEM).
"""

from __future__ import annotations

import argparse
import dataclasses
import math

from .config import Bucket, KernelConfig, ModelConfig, cdiv

F32 = 4
VMEM_BYTES = 16 * 2 ** 20          # per-core VMEM on current TPUs
MXU_FLOPS_PER_CYCLE = 2 * 128 * 128   # one 128x128 MAC array


@dataclasses.dataclass(frozen=True)
class ScenarioShape:
    """Analytic stand-in for a batch: uniform sequences."""
    num_seqs: int
    seq_len: int          # context + query
    query_len: int        # tokens per sequence this step (1 = decode)


@dataclasses.dataclass(frozen=True)
class KernelModel:
    variant: str
    #: program instances launched (the grid size)
    instances: int
    #: serial tile-loop iterations on the longest instance (critical path)
    critical_path_tiles: int
    #: f32 bytes resident per instance (Q block + K/V tiles + accumulators)
    vmem_bytes: int
    #: fraction of FLOPs eligible for the MXU (dot path)
    mxu_fraction: float
    #: K/V bytes loaded from HBM across all instances (redundancy shows here)
    hbm_bytes: int
    #: total FLOPs across instances
    flops: int

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    @property
    def parallel_tile_steps(self) -> int:
        """Idealized wave count on an infinitely parallel device: the
        longest serial chain of tile iterations."""
        return self.critical_path_tiles


def _tile_flops(m: int, n: int, d: int) -> int:
    # scores (m×d · d×n) + weighted sum (m×n · n×d), MACs×2
    return 2 * m * n * d * 2


def model_kernel(cfg: KernelConfig, geom: ModelConfig,
                 shape: ScenarioShape) -> KernelModel:
    """Analytic model of one kernel launch for a uniform batch."""
    d = geom.head_size
    kvh = geom.num_kv_heads
    qh = geom.num_q_heads
    qpk = geom.queries_per_kv
    tiles_per_seq = cdiv(shape.seq_len, cfg.tile_n)
    kv_tile_bytes = 2 * cfg.tile_n * d * F32        # K and V tiles

    if cfg.variant == "naive":
        # one (token, head) per instance; elementwise path; every instance
        # re-loads its KV head's tiles → qpk-fold redundancy vs qblock
        inst = shape.num_seqs * shape.query_len * qh
        m = 1
        vmem = (m * d + 2 * cfg.tile_n * d + m * cfg.tile_n + m * d) * F32
        hbm = inst * tiles_per_seq * kv_tile_bytes
        flops = inst * tiles_per_seq * _tile_flops(m, cfg.tile_n, d)
        return KernelModel(cfg.variant, inst, tiles_per_seq, vmem,
                           1.0 if cfg.use_dot else 0.0, hbm, flops)

    if cfg.variant in ("qblock", "static", "flash"):
        m = cfg.block_q * qpk
        qblocks = shape.num_seqs * cdiv(shape.query_len, cfg.block_q)
        inst = (cfg.static_programs * kvh if cfg.variant == "static"
                else qblocks * kvh)
        work_per_prog = (cdiv(qblocks, cfg.static_programs)
                         if cfg.variant == "static" else 1)
        vmem = (m * d + 2 * cfg.tile_n * d + m * cfg.tile_n + m * d) * F32
        hbm = qblocks * kvh * tiles_per_seq * kv_tile_bytes
        flops = qblocks * kvh * tiles_per_seq * _tile_flops(m, cfg.tile_n, d)
        return KernelModel(cfg.variant, inst,
                           work_per_prog * tiles_per_seq, vmem,
                           1.0 if cfg.use_dot else 0.0, hbm, flops)

    if cfg.variant == "parts":
        # decode-only: segments divide the per-sequence tile chain, plus a
        # reduction pass over num_segments partials (§4.5)
        m = qpk
        inst = shape.num_seqs * kvh * cfg.num_segments
        tiles_per_segment = cdiv(tiles_per_seq, cfg.num_segments)
        vmem = (m * d + 2 * cfg.tile_n * d + m * cfg.tile_n + m * d) * F32
        hbm = shape.num_seqs * kvh * tiles_per_seq * kv_tile_bytes
        flops = shape.num_seqs * kvh * tiles_per_seq * _tile_flops(
            m, cfg.tile_n, d)
        # +1: the reduce_segments kernel counts as one extra serial step
        return KernelModel(cfg.variant, inst, tiles_per_segment + 1, vmem,
                           1.0 if cfg.use_dot else 0.0, hbm, flops)

    raise ValueError(cfg.variant)


def mxu_utilization_estimate(cfg: KernelConfig, geom: ModelConfig) -> float:
    """Fraction of MXU lanes a dot-path tile occupies: (m×n×d) contraction
    mapped onto 128×128 MACs — the paper's Tensor-Core-occupancy analogue."""
    if not cfg.use_dot:
        return 0.0
    m = cfg.block_q * geom.queries_per_kv
    return min(1.0, m / 128) * min(1.0, cfg.tile_n / 128)


def report(cfg: KernelConfig, geom: ModelConfig, shape: ScenarioShape) -> str:
    km = model_kernel(cfg, geom, shape)
    return (f"{cfg.tag():<38} inst={km.instances:<6} "
            f"crit_path={km.parallel_tile_steps:<5} "
            f"vmem={km.vmem_bytes / 1024:>6.1f}KiB "
            f"mxu={km.mxu_fraction:>4.0%} "
            f"hbm={km.hbm_bytes / 1e6:>7.2f}MB "
            f"ai={km.arithmetic_intensity:>5.2f}")


def main() -> None:
    from .aot import KERNEL_GEOM, PROFILES

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", default="default", choices=list(PROFILES))
    ap.add_argument("--num-seqs", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=1024)
    ap.add_argument("--query-len", type=int, default=1)
    args = ap.parse_args()

    shape = ScenarioShape(args.num_seqs, args.seq_len, args.query_len)
    arts, _ = PROFILES[args.profile]()
    seen = set()
    print(f"# analytic kernel model — batch={shape.num_seqs} "
          f"seqlen={shape.seq_len} qlen={shape.query_len}")
    for a in arts:
        if a.kind != "kernel" or a.cfg in seen:
            continue
        seen.add(a.cfg)
        if a.cfg.variant == "parts" and shape.query_len != 1:
            continue
        print(report(a.cfg, KERNEL_GEOM, shape))


if __name__ == "__main__":
    main()
