#!/usr/bin/env python3
"""Offline behavioral port of the Rust serving engine's bench matrix.

Regenerates BENCH_baseline.json on machines without a Rust toolchain by
replaying the exact integer/f64 arithmetic of the engine (scheduler, paged
KV cache, sim sampler, output pipeline) in pure stdlib Python. Counters are
bit-exact with `repro bench`; wall-clock timings are emitted as zeros (only
counters gate — see docs/BENCHMARKS.md).

Usage:
  python3 python/bench_port/gen_baseline.py --validate   # check the port
  python3 python/bench_port/gen_baseline.py --out BENCH_baseline.json
"""

import argparse
import json
import math
import os
import struct
import sys
from collections import OrderedDict, deque

MASK = (1 << 64) - 1
FNV_MUL = 0x100000001B3
HASH_SEED = 0xCBF29CE484222325

VOCAB = 2048
MAX_MODEL_LEN = 512
NUM_SLOTS = 208
BLOCK_SIZE = 16
ENVELOPE_MAX_TOKENS = 128
ENVELOPE_MAX_SEQS = 8

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

DECODE_FIRST = "decode_first"
LEGACY_MIXED = "legacy_mixed"

INTERACTIVE = "interactive"
BATCH = "batch"


def compute_wseed():
    """Fold the tiny model's weight stream exactly like the sim runtime."""
    data = open(os.path.join(REPO, "rust", "artifacts", "tiny.weights.bin"), "rb").read()
    ws = 0x9E3779B97F4A7C15
    for (bits,) in struct.iter_unpack("<I", data):
        ws = ((ws ^ bits) * FNV_MUL) & MASK
    return ws


WSEED = compute_wseed()


def raw_sample(stream):
    """FNV chain over (token ^ (pos << 20)) for the row's fed stream."""
    h = (HASH_SEED ^ WSEED) & MASK
    for p, t in enumerate(stream):
        kv = (t & MASK) ^ ((p << 20) & MASK)
        h = ((h ^ kv) * FNV_MUL) & MASK
    return h % VOCAB


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def rotl64(x, n):
    return ((x << n) | (x >> (64 - n))) & MASK


def logprob_proxy(tok):
    return math.log((tok + 1) / max(VOCAB, 1))


def cdiv(a, b):
    return -(-a // b)


# ---------------------------------------------------------------------------
# Sampling params (config.rs)
# ---------------------------------------------------------------------------


class SamplingParams:
    def __init__(self, n=1, seed=0, temperature=0.0, beam=None,
                 stop_token_ids=None, stop_sequences=None):
        self.n = n
        self.seed = seed & MASK
        self.temperature = temperature
        self.beam = beam  # None or dict(width, length_penalty, early_stopping)
        self.stop_token_ids = list(stop_token_ids or [])
        self.stop_sequences = [list(s) for s in (stop_sequences or [])]

    @staticmethod
    def greedy():
        return SamplingParams()

    @staticmethod
    def beam_params(width, length_penalty, seed):
        return SamplingParams(n=width, seed=seed, temperature=0.0,
                              beam=dict(width=width, length_penalty=length_penalty,
                                        early_stopping=False))

    def with_early_stopping(self, v):
        self.beam["early_stopping"] = v
        return self

    def is_beam(self):
        return self.beam is not None

    def is_greedy(self):
        return (self.beam is None and self.n == 1 and self.seed == 0
                and self.temperature == 0.0)

    def width(self):
        return self.beam["width"] if self.beam else self.n

    def salt_for(self, branch):
        if self.is_greedy():
            return 0
        h = (0x9E3779B97F4A7C15 ^ self.seed) & MASK
        h = ((h ^ (branch & MASK)) * FNV_MUL) & MASK
        h = ((h ^ f64_bits(self.temperature)) * FNV_MUL) & MASK
        return h | 1

    def sample(self, raw, branch):
        salt = self.salt_for(branch)
        if salt == 0:
            return raw
        mixed = (((raw & 0xFFFFFFFF) ^ salt) * 0x2545F4914F6CDD1D) & MASK
        return (mixed >> 17) % max(VOCAB, 1)

    def beam_candidates(self, raw):
        width = min(self.beam["width"], max(VOCAB, 1))
        out = []
        chosen = set()
        for j in range(width):
            h = ((raw & 0xFFFFFFFF) ^ rotl64(self.seed, 17) ^ 0xA0761D6478BD642F) & MASK
            h = ((h ^ j) * FNV_MUL) & MASK
            h ^= h >> 29
            h = (h * 0xBF58476D1CE4E5B9) & MASK
            h ^= h >> 32
            token = h % VOCAB
            while token in chosen:
                token = (token + 1) % VOCAB
            chosen.add(token)
            u = ((h >> 11) | 1) / float(1 << 53)
            lp = math.log(u) - 0.02 * j
            out.append((token, lp))
        return out

    def hit_stop(self, output):
        if output and output[-1] in self.stop_token_ids:
            return True
        for seq in self.stop_sequences:
            if seq and len(output) >= len(seq) and output[-len(seq):] == seq:
                return True
        return False

    def hit_stop_with(self, output, nxt):
        if nxt in self.stop_token_ids:
            return True
        for seq in self.stop_sequences:
            if not seq or seq[-1] != nxt:
                continue
            head = seq[:-1]
            if len(output) >= len(head) and (not head or output[-len(head):] == head):
                return True
        return False


# ---------------------------------------------------------------------------
# Engine config (config.rs)
# ---------------------------------------------------------------------------


class EngineConfig:
    def __init__(self):
        self.block_size = BLOCK_SIZE
        self.max_batched_tokens = 256
        self.max_num_seqs = 8
        self.watermark = 2
        self.caching = True
        self.sched_policy = DECODE_FIRST
        self.max_prefill_tokens_per_step = 0
        self.tenant_weights = {}

    def prefill_budget(self):
        if self.max_prefill_tokens_per_step == 0:
            return self.max_batched_tokens
        return min(self.max_prefill_tokens_per_step, self.max_batched_tokens)

    def tenant_weight(self, tenant):
        w = self.tenant_weights.get(tenant)
        return max(w, 1) if w is not None else 1


# ---------------------------------------------------------------------------
# Paged KV cache (kvcache.rs)
# ---------------------------------------------------------------------------


def hash_block(prev, toks):
    h = ((prev * FNV_MUL) & MASK) ^ len(toks)
    for t in toks:
        h = ((h ^ (t & 0xFFFFFFFF)) * FNV_MUL) & MASK
    return h


def hasher_update(memo, stream, bs):
    """PrefixHasher::update — extend the per-sequence block-hash memo to
    cover every probe-relevant full block (capped so one token is left to
    compute), returning how many hashes the memo served (the
    prefix_hash_skips unit)."""
    max_full = (len(stream) - 1) // bs if stream else 0
    reused = min(len(memo), max_full)
    chain = memo[-1] if memo else HASH_SEED
    for blk in range(len(memo), max_full):
        chain = hash_block(chain, stream[blk * bs:(blk + 1) * bs])
        memo.append(chain)
    return reused


class BlockTable:
    __slots__ = ("pages", "len", "committed", "chain")

    def __init__(self):
        self.pages = []
        self.len = 0
        self.committed = 0
        self.chain = HASH_SEED


class KvCacheManager:
    def __init__(self, num_slots, block_size, caching):
        self.bs = block_size
        self.caching = caching
        self.num_pages = num_slots // block_size
        # page 0 is scratch; free list pops from the end -> first alloc is 1
        self.free_list = list(range(self.num_pages - 1, 0, -1))
        self.rc = [0] * self.num_pages
        self.tables = []
        self.index = {}  # chain -> page
        self.page_key = [None] * self.num_pages
        self.evictable = {}  # tick -> page
        self.page_tick = [None] * self.num_pages
        self.tick = 0
        self.step = 0
        self.stats = dict(pages_allocated=0, evictions=0, hit_tokens=0,
                          lookup_tokens=0, lookups=0, forked_pages=0, cow_copies=0)

    def advance_step(self):
        self.step += 1

    def free_pages(self):
        return len(self.free_list) + len(self.evictable)

    def register(self):
        for i, t in enumerate(self.tables):
            if t is None:
                self.tables[i] = BlockTable()
                return i
        self.tables.append(BlockTable())
        return len(self.tables) - 1

    def evict_lru(self):
        t = min(self.evictable)
        p = self.evictable.pop(t)
        key = self.page_key[p]
        self.page_key[p] = None
        if key is not None:
            self.index.pop(key, None)
        self.page_tick[p] = None
        self.stats["evictions"] += 1
        return p

    def allocate_page(self):
        if self.free_list:
            p = self.free_list.pop()
        elif self.evictable:
            p = self.evict_lru()
        else:
            return None
        self.rc[p] = 1
        self.stats["pages_allocated"] += 1
        return p

    def release_page(self, p):
        self.rc[p] -= 1
        if self.rc[p] == 0:
            if self.caching and self.page_key[p] is not None:
                self.tick += 1
                self.evictable[self.tick] = p
                self.page_tick[p] = self.tick
            else:
                self.free_list.append(p)

    def acquire_cached(self, p):
        if self.rc[p] > 0:
            self.rc[p] += 1
        else:
            t = self.page_tick[p]
            if t is not None:
                self.evictable.pop(t, None)
                self.page_tick[p] = None
            self.rc[p] = 1

    def lookup_prefix(self, tokens):
        if not self.caching or not tokens:
            return 0
        max_full = (len(tokens) - 1) // self.bs
        hit = 0
        chain = HASH_SEED
        for blk in range(max_full):
            chain = hash_block(chain, tokens[blk * self.bs:(blk + 1) * self.bs])
            if chain in self.index:
                hit = (blk + 1) * self.bs
            else:
                break
        return hit

    def parked_prefix_pages(self, tokens):
        if not self.caching or not tokens:
            return 0
        max_full = (len(tokens) - 1) // self.bs
        parked = 0
        chain = HASH_SEED
        for blk in range(max_full):
            chain = hash_block(chain, tokens[blk * self.bs:(blk + 1) * self.bs])
            if chain in self.index:
                if self.rc[self.index[chain]] == 0:
                    parked += 1
            else:
                break
        return parked

    def attach_prefix(self, h, tokens):
        if not self.caching:
            return 0
        self.stats["lookups"] += 1
        self.stats["lookup_tokens"] += len(tokens)
        max_full = (len(tokens) - 1) // self.bs if tokens else 0
        pages = []
        matched_chain = HASH_SEED
        chain = HASH_SEED
        for blk in range(max_full):
            chain = hash_block(chain, tokens[blk * self.bs:(blk + 1) * self.bs])
            if chain in self.index:
                pages.append(self.index[chain])
                matched_chain = chain
            else:
                break
        if not pages:
            return 0
        for p in pages:
            self.acquire_cached(p)
        t = self.tables[h]
        t.committed = len(pages)
        t.chain = matched_chain
        t.pages = pages
        t.len = len(pages) * self.bs
        cached = len(pages) * self.bs
        self.stats["hit_tokens"] += cached
        return cached

    def commit_prefix(self, h, tokens, computed):
        if not self.caching:
            return
        t = self.tables[h]
        computed = min(computed, len(tokens))
        full = min(computed // self.bs, len(t.pages))
        start = min(t.committed, full)
        if start >= full:
            return
        chain = HASH_SEED if start == 0 else t.chain
        for blk in range(start, full):
            chain = hash_block(chain, tokens[blk * self.bs:(blk + 1) * self.bs])
            p = t.pages[blk]
            if chain in self.index:
                continue
            if self.page_key[p] is None:
                self.index[chain] = p
                self.page_key[p] = chain
        t.committed = full
        t.chain = chain

    def grow(self, h, new_total):
        t = self.tables[h]
        need = max(0, cdiv(new_total, self.bs) - len(t.pages))
        if need > self.free_pages():
            return False
        for _ in range(need):
            p = self.allocate_page()
            assert p is not None
            t.pages.append(p)
        t.len = new_total
        return True

    def free(self, h):
        t = self.tables[h]
        self.tables[h] = None
        for p in reversed(t.pages):
            self.release_page(p)

    def free_counting(self, h):
        n = len(self.tables[h].pages)
        self.free(h)
        return n

    def fork(self, parent):
        src = self.tables[parent]
        h = self.register()
        t = self.tables[h]
        t.pages = list(src.pages)
        t.len = src.len
        t.committed = src.committed
        t.chain = src.chain
        for p in t.pages:
            self.rc[p] += 1
        self.stats["forked_pages"] += len(t.pages)
        return h

    def unshare_last(self, h):
        """Returns (ok, pair): ok=False models the Rust Err (pool exhausted)."""
        t = self.tables[h]
        if not t.pages or self.rc[t.pages[-1]] == 1:
            return True, None
        fresh = self.allocate_page()
        if fresh is None:
            return False, None
        old = t.pages[-1]
        t.pages[-1] = fresh
        self.release_page(old)
        self.stats["cow_copies"] += 1
        return True, (old, fresh)

    def pages_needed_from(self, cached, new_total):
        return max(0, cdiv(new_total, self.bs) - cached // self.bs)

    def committed_blocks(self, h):
        return self.tables[h].committed


# ---------------------------------------------------------------------------
# Scheduler (scheduler.rs)
# ---------------------------------------------------------------------------

PASS_DECODES = "decodes"
PASS_PREFILLS = "prefills"
PASS_MIXED = "mixed"

MAX_SELF_PREEMPTS = 8

FINISHED_STATES = ("finished_stop", "finished_length")


class Sequence:
    __slots__ = ("branch", "state", "output", "logprobs", "handle", "computed",
                 "cum_logprob", "pending", "stall", "hash_memo")

    def __init__(self, branch, state="waiting", output=None, logprobs=None,
                 handle=None, computed=0, cum_logprob=0.0, pending=None, stall=0):
        self.branch = branch
        self.state = state
        self.output = output if output is not None else []
        self.logprobs = logprobs if logprobs is not None else []
        self.handle = handle
        self.computed = computed
        self.cum_logprob = cum_logprob
        self.pending = pending
        self.stall = stall
        # rolling block-hash memo (kvcache.rs PrefixHasher); survives
        # preemption, fork children start fresh
        self.hash_memo = []

    def is_finished(self):
        return self.state in FINISHED_STATES


class Group:
    def __init__(self, gid, prompt, sampling, max_new, arrival_seq, priority, tenant):
        self.id = gid
        self.prompt = list(prompt)
        self.sampling = sampling
        self.max_new = max(max_new, 1)
        self.arrival_seq = arrival_seq
        self.priority = priority
        self.tenant = tenant
        self.seqs = [Sequence(branch=0)]
        self.next_branch = 1
        self.forked = False
        self.admitted = False
        self.cached_tokens = 0
        self.self_preempts = 0
        self.preemptions = 0
        self.first_token_ns = None

    def stream(self, branch):
        return self.prompt + self.seq(branch).output

    def seq(self, branch):
        for s in self.seqs:
            if s.branch == branch:
                return s
        raise KeyError(branch)

    def seq_index(self, branch):
        for i, s in enumerate(self.seqs):
            if s.branch == branch:
                return i
        raise KeyError(branch)

    def token_at(self, branch, i):
        if i < len(self.prompt):
            return self.prompt[i]
        return self.seq(branch).output[i - len(self.prompt)]

    def is_finished(self):
        return all(s.is_finished() for s in self.seqs)

    def reserved_rows(self):
        live = sum(1 for s in self.seqs if not s.is_finished())
        extra = 0 if self.forked else max(0, self.sampling.width() - len(self.seqs))
        return live + extra

    def final_score(self, s):
        if self.sampling.is_beam():
            lp = self.sampling.beam["length_penalty"]
            return s.cum_logprob / (max(len(s.output), 1) ** lp)
        return 0.0

    def best_attainable(self, s):
        lp = self.sampling.beam["length_penalty"]
        if lp > 0.0:
            length = max(self.max_new, 1)
        else:
            length = max(len(s.output), 1)
        return s.cum_logprob / (length ** lp)


class Row:
    __slots__ = ("id", "branch", "handle", "ctx_len", "tokens", "samples", "prefill")

    def __init__(self, gid, branch, handle, ctx_len, tokens, samples, prefill):
        self.id = gid
        self.branch = branch
        self.handle = handle
        self.ctx_len = ctx_len
        self.tokens = tokens
        self.samples = samples
        self.prefill = prefill


class Batch:
    def __init__(self):
        self.seqs = []
        self.preempted = []
        self.cow_copies = []


INF_BUDGET = 1 << 62


class Scheduler:
    def __init__(self, cfg):
        self.cfg = cfg
        self.running = []
        self.waiting = {}  # tenant -> deque[Group]
        self.finished = []
        self.next_arrival = 0
        self.drr_cursor = None
        self.deficit = {}
        self.stats = dict(steps=0, scheduled_tokens=0, preemptions=0,
                          self_preemptions=0, decode_stall_steps=0,
                          max_decode_gap_steps=0, prefill_chunk_deferrals=0,
                          prefix_hash_skips=0, cached_tokens=0,
                          forked_branches=0, wfq={})

    def add_group_with(self, group):
        assert group.prompt
        assert group.sampling.width() >= 1
        group.arrival_seq = self.next_arrival
        self.next_arrival += 1
        q = self.waiting.setdefault(group.tenant, deque())
        if group.priority == INTERACTIVE:
            pos = len(q)
            for i, og in enumerate(q):
                if og.priority == BATCH:
                    pos = i
                    break
            q.insert(pos, group)
        else:
            q.append(group)

    def has_unfinished(self):
        return any(self.waiting.values()) or bool(self.running)

    def live_rows(self):
        """Scheduler::live_rows — waiting widths + running reservations,
        the load half of the shard status the router places by."""
        waiting = sum(g.sampling.width()
                      for q in self.waiting.values() for g in q)
        return waiting + sum(g.reserved_rows() for g in self.running)

    def take_finished(self):
        out = self.finished
        self.finished = []
        return out

    def group_by_id(self, gid):
        for g in self.running:
            if g.id == gid:
                return g
        return None

    def schedule(self, kv):
        kv.advance_step()
        batch = Batch()
        while True:
            self.schedule_pass(batch, kv)
            if batch.seqs or not self.has_unfinished() or not self.self_preempt_parked(kv):
                break
        self.note_decode_stalls(batch)
        self.stats["steps"] += 1
        self.stats["scheduled_tokens"] += sum(len(r.tokens) for r in batch.seqs)
        return batch

    def schedule_pass(self, batch, kv):
        st = {
            "budget": self.cfg.max_batched_tokens,
            "prefill_budget": (self.cfg.prefill_budget()
                               if self.cfg.sched_policy == DECODE_FIRST else INF_BUDGET),
        }
        scheduled = set()
        self.running.sort(key=lambda g: g.arrival_seq)
        decode_first = self.cfg.sched_policy == DECODE_FIRST
        if decode_first:
            if self.continuations(PASS_DECODES, batch, kv, st, scheduled):
                self.continuations(PASS_PREFILLS, batch, kv, st, scheduled)
        else:
            self.continuations(PASS_MIXED, batch, kv, st, scheduled)
        while (st["budget"] > 0 and st["prefill_budget"] > 0
               and len(batch.seqs) < self.cfg.max_num_seqs):
            r = self.admit_resumption(batch, kv, st)
            if r is True:
                continue
            if r is False:
                break
            if decode_first:
                if not self.admit_drr(batch, kv, st):
                    break
            else:
                t = self.fcfs_tenant()
                if t is None:
                    break
                if self.try_admit_front(t, False, batch, kv, st) != "admitted":
                    break

    def continuations(self, pk, batch, kv, st, scheduled):
        gi = 0
        done = False
        while gi < len(self.running) and not done:
            if st["budget"] == 0:
                break
            g = self.running[gi]
            bi = 0
            while bi < len(g.seqs):
                if st["budget"] == 0:
                    done = True
                    break
                s = g.seqs[bi]
                if s.state != "running":
                    bi += 1
                    continue
                total = len(g.prompt) + len(s.output)
                if s.pending is not None and s.computed >= total:
                    bi += 1
                    continue
                is_prefill = s.computed < total
                is_decode = bool(s.output) and s.computed + 1 >= total
                if (pk == PASS_DECODES and not is_decode) or \
                   (pk == PASS_PREFILLS and is_decode):
                    bi += 1
                    continue
                if is_decode:
                    n_new = 1
                    samples = True
                else:
                    want = min(total - s.computed, st["budget"])
                    n = min(want, st["prefill_budget"])
                    if n < want:
                        self.stats["prefill_chunk_deferrals"] += 1
                    if n == 0:
                        bi += 1
                        continue
                    n_new = n
                    samples = s.computed + n == total
                target = total + 1 if s.computed >= total else s.computed + n_new
                ok = True
                pair = None
                if s.computed % self.cfg.block_size != 0:
                    ok, pair = kv.unshare_last(s.handle)
                if ok and pair is not None:
                    batch.cow_copies.append(pair)
                if not ok or not kv.grow(s.handle, target):
                    j = self.pick_victim(g.id, scheduled)
                    if j is None:
                        return False
                    self.preempt(j, batch, kv)
                    if j < gi:
                        gi -= 1
                    continue  # retry the same branch
                if is_prefill:
                    tokens = [g.token_at(s.branch, i)
                              for i in range(s.computed, s.computed + n_new)]
                else:
                    tokens = [s.output[-1] if s.output else g.prompt[-1]]
                st["budget"] -= min(len(tokens), st["budget"])
                if not is_decode:
                    st["prefill_budget"] = max(0, st["prefill_budget"] - len(tokens))
                batch.seqs.append(Row(g.id, s.branch, s.handle, s.computed,
                                      tokens, samples, is_prefill))
                scheduled.add(g.id)
                bi += 1
            gi += 1
        return True

    def note_decode_stalls(self, batch):
        if not batch.seqs:
            return
        in_batch = {(r.id, r.branch) for r in batch.seqs}
        for g in self.running:
            for s in g.seqs:
                ready = (s.state == "running" and s.pending is None
                         and bool(s.output)
                         and s.computed + 1 >= len(g.prompt) + len(s.output))
                if not ready or (g.id, s.branch) in in_batch:
                    s.stall = 0
                else:
                    s.stall += 1
                    self.stats["decode_stall_steps"] += 1
                    self.stats["max_decode_gap_steps"] = max(
                        self.stats["max_decode_gap_steps"], s.stall)

    def self_preempt_parked(self, kv):
        for g in self.running:
            if g.self_preempts >= MAX_SELF_PREEMPTS:
                continue
            for s in g.seqs:
                if (s.state == "running" and s.pending is not None
                        and s.handle is not None
                        and s.computed >= len(g.prompt) + len(s.output)):
                    kv.free(s.handle)
                    s.handle = None
                    s.state = "waiting"
                    s.computed = 0
                    s.stall = 0
                    g.self_preempts += 1
                    g.preemptions += 1
                    self.stats["self_preemptions"] += 1
                    return True
        return False

    def admit_resumption(self, batch, kv, st):
        for gi, g in enumerate(self.running):
            for bi, s in enumerate(g.seqs):
                if s.state == "waiting":
                    res = self.admit_branch(None, False, gi, bi, batch, kv, st)
                    return res == "admitted"
        return None

    def fcfs_tenant(self):
        best = None
        for t, q in self.waiting.items():
            if not q:
                continue
            if best is None or q[0].arrival_seq < self.waiting[best][0].arrival_seq:
                best = t
        return best

    def admit_drr(self, batch, kv, st):
        quantum = max(self.cfg.block_size, 1)
        admitted_total = False
        while True:
            if (st["budget"] == 0 or st["prefill_budget"] == 0
                    or len(batch.seqs) >= self.cfg.max_num_seqs):
                return admitted_total
            tenants = sorted(t for t, q in self.waiting.items() if q)
            if not tenants:
                return admitted_total
            start = 0
            if self.drr_cursor is not None:
                for i, t in enumerate(tenants):
                    if t > self.drr_cursor:
                        start = i
                        break
            admitted_any = False
            deficit_limited = False
            for k in range(len(tenants)):
                t = tenants[(start + k) % len(tenants)]
                self.deficit[t] = (self.deficit.get(t, 0)
                                   + quantum * self.cfg.tenant_weight(t))
                while True:
                    if (st["budget"] == 0 or st["prefill_budget"] == 0
                            or len(batch.seqs) >= self.cfg.max_num_seqs):
                        return admitted_total
                    res = self.try_admit_front(t, True, batch, kv, st)
                    if res == "admitted":
                        admitted_any = True
                        admitted_total = True
                        self.drr_cursor = t
                        continue
                    if res == "deficit":
                        deficit_limited = True
                    break
            if not admitted_any and not deficit_limited:
                return admitted_total

    def try_admit_front(self, tenant, enforce, batch, kv, st):
        q = self.waiting.get(tenant)
        if not q:
            return "blocked"
        g = q[0]
        if self.reserved_rows_total() + g.reserved_rows() > self.cfg.max_num_seqs:
            return "blocked"
        bi = None
        for i, s in enumerate(g.seqs):
            if s.state == "waiting":
                bi = i
                break
        if bi is None:
            return "blocked"
        return self.admit_branch(tenant, enforce, None, bi, batch, kv, st)

    def admit_branch(self, tenant, enforce, gi, bi, batch, kv, st):
        from_queue = tenant is not None
        g = self.waiting[tenant][0] if from_queue else self.running[gi]
        s = g.seqs[bi]
        stream = g.stream(s.branch)
        total = len(stream)
        # memo update first, mirroring Rust: skips are counted per probe,
        # including attempts that end DeficitLimited or Blocked below
        if kv.caching:
            self.stats["prefix_hash_skips"] += hasher_update(
                s.hash_memo, stream, kv.bs)
        cached = kv.lookup_prefix(stream)
        uncached = total - cached
        if enforce and self.deficit.get(tenant, 0) < uncached:
            return "deficit"
        chunk = min(uncached, st["budget"], st["prefill_budget"])
        if chunk == 0:
            return "blocked"
        need = kv.pages_needed_from(cached, cached + chunk)
        parked = kv.parked_prefix_pages(stream)
        if kv.free_pages() < parked + need + self.cfg.watermark:
            return "blocked"
        handle = kv.register()
        kv.attach_prefix(handle, stream)
        if not kv.grow(handle, cached + chunk):
            kv.free(handle)
            return "blocked"
        tokens = stream[cached:cached + chunk]
        st["budget"] -= chunk
        st["prefill_budget"] = max(0, st["prefill_budget"] - chunk)
        self.stats["cached_tokens"] += cached
        if enforce:
            self.deficit[tenant] = max(0, self.deficit[tenant] - uncached)
        if from_queue:
            self.stats["wfq"][tenant] = self.stats["wfq"].get(tenant, 0) + uncached
            q = self.waiting[tenant]
            q.popleft()
            if not q:
                del self.waiting[tenant]
                self.deficit.pop(tenant, None)
            self.running.append(g)
        if not g.admitted:
            g.admitted = True
            g.cached_tokens = cached
        s.state = "running"
        s.handle = handle
        s.computed = cached
        batch.seqs.append(Row(g.id, s.branch, handle, cached, tokens,
                              cached + chunk == total, True))
        return "admitted"

    def reserved_rows_total(self):
        return sum(g.reserved_rows() for g in self.running)

    def recompute_cost(self, g, kv):
        cost = 0
        for s in g.seqs:
            if s.state == "running" and s.handle is not None:
                cost += max(0, s.computed - kv.committed_blocks(s.handle) * self.cfg.block_size)
        return cost

    def pick_victim(self, current_id, scheduled, kv=None):
        cands = []
        for j, g in enumerate(self.running):
            if g.id == current_id or g.id in scheduled:
                continue
            if not any(s.state == "running" for s in g.seqs):
                continue
            cands.append(j)
        if not cands:
            return None
        return min(cands, key=lambda j: (self.recompute_cost(self.running[j], self._kv),
                                         -self.running[j].arrival_seq))

    def preempt(self, j, batch, kv):
        g = self.running.pop(j)
        for s in g.seqs:
            if s.handle is not None:
                kv.free(s.handle)
                s.handle = None
            if s.state == "running":
                s.state = "waiting"
                s.computed = 0
            s.stall = 0
        g.preemptions += 1
        self.stats["preemptions"] += 1
        batch.preempted.append(g.id)
        self.waiting.setdefault(g.tenant, deque()).appendleft(g)


# ---------------------------------------------------------------------------
# Output pipeline (output.rs)
# ---------------------------------------------------------------------------


class StepOutputs:
    def __init__(self):
        self.tokens = 0  # TokenEvent count
        self.appended = 0
        self.finished = 0


class Candidate:
    __slots__ = ("cum", "lp", "branch", "ci", "token")

    def __init__(self, cum, lp, branch, ci, token):
        self.cum = cum
        self.lp = lp
        self.branch = branch
        self.ci = ci
        self.token = token


class OutputProcessor:
    def process(self, sched, batch, samples, kv, m):
        out = StepOutputs()
        # Stage 1: bookkeeping + parallel sampling
        for row in batch.seqs:
            g = sched.group_by_id(row.id)
            if g is None:
                continue
            pos = g.seq_index(row.branch)
            s = g.seqs[pos]
            s.computed = row.ctx_len + len(row.tokens)
            if (kv.caching and s.handle is not None
                    and s.computed // kv.bs > kv.committed_blocks(s.handle)):
                known = [g.token_at(row.branch, i) for i in range(s.computed)]
                kv.commit_prefix(s.handle, known, s.computed)
            if not row.samples:
                continue
            raw = samples.get((row.id, row.branch))
            if raw is None:
                continue
            if s.computed < len(g.prompt) + len(s.output):
                continue  # replay after preemption
            if g.sampling.is_beam():
                s.pending = raw
                continue
            tok = g.sampling.sample(raw, row.branch)
            lp = logprob_proxy(tok)
            self.apply_token(g, pos, tok, lp, out, stream=True)
            n = g.sampling.n
            if (not g.forked and n > 1 and row.branch == 0
                    and len(g.seqs[pos].output) == 1):
                parent = g.seqs[pos].handle
                computed0 = g.seqs[pos].computed
                for b in range(1, n):
                    h = kv.fork(parent)
                    first = g.sampling.sample(raw, b)
                    flp = logprob_proxy(first)
                    g.seqs.append(Sequence(branch=b, state="running",
                                           output=[first], logprobs=[flp],
                                           handle=h, computed=computed0))
                    g.next_branch = b + 1
                    sched.stats["forked_branches"] += 1
                    out.appended += 1
                    out.tokens += 1
                g.forked = True
        # Stage 2: beam expansion
        for g in sched.running:
            if g.sampling.is_beam():
                self.expand_beam(g, kv, m, out)
        # Stage 3: stop conditions / length caps
        for g in sched.running:
            for s in g.seqs:
                if s.is_finished():
                    continue
                if g.sampling.hit_stop(s.output):
                    s.state = "finished_stop"
                    m["stop_finishes"] += 1
                    out.finished += 1
                elif len(s.output) >= g.max_new:
                    s.state = "finished_length"
                    out.finished += 1
        # Stage 4: free finished handles, retire finished groups
        j = 0
        while j < len(sched.running):
            g = sched.running[j]
            for s in g.seqs:
                if s.is_finished() and s.handle is not None:
                    kv.free(s.handle)
                    s.handle = None
            if g.is_finished():
                sched.running.pop(j)
                if g.sampling.is_beam():
                    order = sorted(g.seqs,
                                   key=lambda s: (-g.final_score(s), s.branch))
                    g.seqs = order[:g.sampling.width()]
                    for s in g.seqs:
                        out.tokens += len(s.output)
                sched.finished.append(g)
            else:
                j += 1
        return out

    def apply_token(self, g, pos, token, lp, out, stream):
        s = g.seqs[pos]
        s.output.append(token)
        s.logprobs.append(lp)
        out.appended += 1
        if stream:
            out.tokens += 1
        if g.first_token_ns is None:
            g.first_token_ns = 0

    def retire_live(self, g, kv, m, indices):
        for i in reversed(indices):
            s = g.seqs.pop(i)
            if s.handle is not None:
                m["beam_pruned_pages"] += kv.free_counting(s.handle)
                s.handle = None
            m["beam_prunes"] += 1

    def expand_beam(self, g, kv, m, out):
        width = g.sampling.beam["width"]
        live = [i for i, s in enumerate(g.seqs) if not s.is_finished()]
        if not live:
            return
        if any(g.seqs[i].pending is None for i in live):
            return
        fin_scores = sorted((g.final_score(s) for s in g.seqs if s.is_finished()),
                            reverse=True)
        if len(fin_scores) >= width:
            best_live = float("-inf")
            for i in live:
                best_live = max(best_live, g.best_attainable(g.seqs[i]))
            if g.sampling.beam["early_stopping"] or best_live <= fin_scores[width - 1]:
                self.retire_live(g, kv, m, live)
                m["beam_early_terminations"] += 1
                g.forked = True
                return
        pool_start = g.next_branch
        cands = []
        pool_new = []
        for i in live:
            s = g.seqs[i]
            raw = s.pending
            stopped = []
            for ci, (token, lp) in enumerate(g.sampling.beam_candidates(raw)):
                if g.sampling.hit_stop_with(s.output, token):
                    stopped.append((token, lp))
                else:
                    cands.append(Candidate(s.cum_logprob + lp, lp, s.branch, ci, token))
            for token, lp in stopped:
                pool_new.append(Sequence(branch=g.next_branch, state="finished_stop",
                                         output=s.output + [token],
                                         logprobs=s.logprobs + [lp],
                                         cum_logprob=s.cum_logprob + lp))
                g.next_branch += 1
        if pool_new and g.first_token_ns is None:
            g.first_token_ns = 0
        cands.sort(key=lambda c: (-c.cum, c.branch, c.ci))
        del cands[width:]
        retired = []
        children = []
        for i in live:
            s = g.seqs[i]
            mine = [(c.token, c.cum, c.lp) for c in cands if c.branch == s.branch]
            if not mine:
                retired.append(i)
                continue
            base = list(s.output)
            base_lps = list(s.logprobs)
            s.pending = None
            s.cum_logprob = mine[0][1]
            self.apply_token(g, i, mine[0][0], mine[0][2], out, stream=False)
            for token, cum, lp in mine[1:]:
                if s.handle is not None:
                    h = kv.fork(s.handle)
                    computed = s.computed
                    state = "running"
                else:
                    h = None
                    computed = 0
                    state = "waiting"
                children.append(Sequence(branch=g.next_branch, state=state,
                                         output=base + [token],
                                         logprobs=base_lps + [lp],
                                         handle=h, computed=computed,
                                         cum_logprob=cum))
                g.next_branch += 1
                m["beam_forks"] += 1
                out.appended += 1
        self.retire_live(g, kv, m, retired)
        g.seqs.extend(children)
        g.seqs.extend(pool_new)
        fins = [i for i, s in enumerate(g.seqs) if s.is_finished()]
        if len(fins) > width:
            order = sorted(fins, key=lambda i: (-g.final_score(g.seqs[i]),
                                                g.seqs[i].branch))
            for i in sorted(order[width:], reverse=True):
                s = g.seqs.pop(i)
                if s.handle is not None:
                    kv.free(s.handle)
        for s in g.seqs:
            if s.is_finished() and s.branch >= pool_start:
                out.finished += 1
                m["beam_finished_hyps"] += 1
                m["stop_finishes"] += 1
                out.appended += 1
        g.forked = True
        g.self_preempts = 0


# ---------------------------------------------------------------------------
# Engine (engine.rs)
# ---------------------------------------------------------------------------


def fresh_metrics():
    return dict(steps=0, generated_tokens=0, prompt_tokens=0, preemptions=0,
                self_preemptions=0, groups_finished=0, cancelled_groups=0,
                pages_allocated=0,
                forked_pages=0, cow_copies=0, prefix_hit_tokens=0,
                prefix_lookup_tokens=0, prefix_evictions=0, stop_finishes=0,
                beam_forks=0, beam_prunes=0, beam_pruned_pages=0,
                beam_finished_hyps=0, beam_early_terminations=0, token_events=0,
                decode_stall_steps=0, max_decode_gap_steps=0,
                prefill_chunk_deferrals=0, arena_reuses=0, arena_grows=0,
                prefix_hash_skips=0, wfq_admitted_tokens={})


class Engine:
    def __init__(self, cfg):
        cfg.max_batched_tokens = min(cfg.max_batched_tokens, ENVELOPE_MAX_TOKENS)
        cfg.max_num_seqs = min(cfg.max_num_seqs, ENVELOPE_MAX_SEQS)
        self.cfg = cfg
        self.kv = KvCacheManager(NUM_SLOTS, BLOCK_SIZE, cfg.caching)
        self.sched = Scheduler(cfg)
        self.sched._kv = self.kv  # pick_victim cost needs committed_blocks
        self.out_proc = OutputProcessor()
        self.next_id = 1
        self.m = fresh_metrics()
        # StepArena demand high-water marks (engine.rs): rows / new tokens
        self.arena_rows = 0
        self.arena_toks = 0

    def warmup(self):
        pass  # precompile only; no counter effects

    def add_group(self, prompt, sampling, max_new, priority=INTERACTIVE,
                  tenant="default"):
        width = sampling.width()
        assert 1 <= width <= self.cfg.max_num_seqs and width <= VOCAB
        assert all(0 <= t < VOCAB for t in prompt)
        limit = MAX_MODEL_LEN - len(prompt)
        assert limit > 0
        gid = self.next_id
        self.next_id += 1
        g = Group(gid, prompt, sampling, min(max_new, limit), 0, priority, tenant)
        self.sched.add_group_with(g)
        return gid

    def add_group_routed(self, prompt, sampling, max_new, memo,
                         priority=INTERACTIVE, tenant="default"):
        """Engine::add_group_routed — the sharded tier's entry point: the
        router's block-hash memo seeds the root branch, so admission
        probes reuse it (each seeded block counts in prefix_hash_skips)."""
        gid = self.add_group(prompt, sampling, max_new, priority, tenant)
        for g in self.sched.waiting[tenant]:
            if g.id == gid:
                g.seqs[0].hash_memo = list(memo)
                return gid
        raise KeyError(gid)

    def live_rows(self):
        return self.sched.live_rows()

    def step(self):
        batch = self.sched.schedule(self.kv)
        st = self.sched.stats
        m = self.m
        m["self_preemptions"] = st["self_preemptions"]
        m["decode_stall_steps"] = st["decode_stall_steps"]
        m["max_decode_gap_steps"] = st["max_decode_gap_steps"]
        m["prefill_chunk_deferrals"] = st["prefill_chunk_deferrals"]
        m["prefix_hash_skips"] = st["prefix_hash_skips"]
        m["wfq_admitted_tokens"] = dict(st["wfq"])
        if not batch.seqs:
            return None
        # arena accounting, demand-keyed exactly like StepArena (engine.rs)
        rows = len(batch.seqs)
        toks = sum(len(r.tokens) for r in batch.seqs)
        if rows > self.arena_rows or toks > self.arena_toks:
            self.arena_rows = max(self.arena_rows, rows)
            self.arena_toks = max(self.arena_toks, toks)
            m["arena_grows"] += 1
        else:
            m["arena_reuses"] += 1
        samples = {}
        for row in batch.seqs:
            if row.samples:
                g = self.sched.group_by_id(row.id)
                stream = g.stream(row.branch)
                samples[(row.id, row.branch)] = raw_sample(
                    stream[:row.ctx_len + len(row.tokens)])
        outs = self.out_proc.process(self.sched, batch, samples, self.kv, m)
        m["token_events"] += outs.tokens
        m["generated_tokens"] += outs.appended
        for _ in self.sched.take_finished():
            m["groups_finished"] += 1
        m["steps"] += 1
        m["preemptions"] += len(batch.preempted)
        ks = self.kv.stats
        m["prefix_hit_tokens"] = ks["hit_tokens"]
        m["prefix_lookup_tokens"] = ks["lookup_tokens"]
        m["prefix_evictions"] = ks["evictions"]
        m["forked_pages"] = ks["forked_pages"]
        m["cow_copies"] = ks["cow_copies"]
        m["pages_allocated"] = ks["pages_allocated"]
        m["prompt_tokens"] += sum(len(r.tokens) for r in batch.seqs if r.prefill)
        return outs

    def run_to_completion(self):
        while self.sched.has_unfinished():
            if self.step() is None and self.sched.has_unfinished():
                raise RuntimeError("engine stuck with work pending")


# ---------------------------------------------------------------------------
# Workload generators (workload.rs)
# ---------------------------------------------------------------------------


class Rng:
    def __init__(self, seed):
        self.state = max(seed, 1) & MASK

    def next_u64(self):
        x = self.state
        x ^= (x << 13) & MASK
        x ^= x >> 7
        x ^= (x << 17) & MASK
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK

    def below(self, n):
        return self.next_u64() % max(n, 1)

    def range(self, lo, hi):
        return lo + self.below(hi - lo + 1)

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def exponential(self, rate):
        return -math.log(max(self.f64(), 1e-12)) / rate

    def tokens(self, n, vocab=VOCAB):
        return [self.below(vocab) for _ in range(n)]


class Request:
    def __init__(self, prompt, sampling, max_new, priority=INTERACTIVE,
                 tenant="default"):
        self.prompt = prompt
        self.sampling = sampling
        self.max_new = max_new
        self.priority = priority
        self.tenant = tenant


def arrival_process_sample(rng, rate, min_prompt, max_prompt, min_new, max_new, n):
    events = []
    t = 0.0
    for _ in range(n):
        t += rng.exponential(rate)
        plen = rng.range(min_prompt, max_prompt)
        mnew = rng.range(min_new, max_new)
        events.append((t, plen, mnew))
    return events


def best_of_n_requests(n, shared_prefix, tail, max_new, stop_ids, count, rng):
    prefix = rng.tokens(shared_prefix)
    reqs = []
    for i in range(count):
        prompt = prefix + rng.tokens(max(tail, 1))
        sp = SamplingParams(n=n, seed=i + 1, temperature=0.7,
                            stop_token_ids=stop_ids)
        reqs.append(Request(prompt, sp, max_new))
    return reqs


def prefix_replay_wave(shared_prefix, tail, max_new, seed, count):
    rng = Rng(seed)
    prefix = rng.tokens(shared_prefix)
    reqs = []
    for _ in range(count):
        prompt = prefix + rng.tokens(max(tail, 1))
        reqs.append(Request(prompt, SamplingParams.greedy(), max_new))
    return reqs


def beam_bench_requests(early_stopping, count, rng):
    width, penalty, shared_prefix, tail, max_new = 3, 1.0, 24, 6, 8
    stop_ids = list(range(0, VOCAB, 7))
    prefix = rng.tokens(shared_prefix)
    reqs = []
    for i in range(count):
        prompt = prefix + rng.tokens(max(tail, 1))
        sp = SamplingParams.beam_params(width, penalty, i + 1)
        sp.stop_token_ids = stop_ids
        sp.with_early_stopping(early_stopping)
        reqs.append(Request(prompt, sp, max_new))
    return reqs


def long_context_stall_arrivals(rng):
    streams, stream_prompt, stream_new = 3, 6, 12
    long_prompt, long_new = 80, 4
    arrivals = []
    for _ in range(streams):
        arrivals.append((0, Request(rng.tokens(max(stream_prompt, 1)),
                                    SamplingParams.greedy(), stream_new,
                                    INTERACTIVE, "default")))
    arrivals.append((2, Request(rng.tokens(max(long_prompt, 1)),
                                SamplingParams.greedy(), long_new,
                                BATCH, "default")))
    return arrivals


def multi_tenant_storm_requests(rounds, rng):
    tenants = [("acme", 3), ("bligh", 1), ("corto", 2)]
    min_prompt, max_prompt, max_new = 6, 18, 4
    reqs = []
    for _ in range(rounds):
        for tenant, volume in tenants:
            for k in range(volume):
                length = rng.range(min_prompt, max_prompt)
                prompt = rng.tokens(max(length, 1))
                prio = INTERACTIVE if k == 0 else BATCH
                reqs.append(Request(prompt, SamplingParams.greedy(), max_new,
                                    prio, tenant))
    return reqs


# ---------------------------------------------------------------------------
# Prefix-affinity router (router.rs)
# ---------------------------------------------------------------------------

AFFINITY = "affinity"
ROUND_ROBIN = "round-robin"


class Router:
    """Router — placement is a pure function of the admission sequence.

    `place` hashes the prompt's leading full blocks once (the memo is
    returned for the engine to reuse), derives the affinity key, and
    scores shards with the deterministic tuple (live_rows, -free_pages,
    placements, index)."""

    def __init__(self, shards, policy, block_size,
                 affinity_blocks=4, affinity_overflow_rows=4):
        assert shards >= 1 and block_size >= 1
        self.shards = shards
        self.policy = policy
        self.bs = block_size
        self.affinity_blocks = affinity_blocks
        self.overflow = affinity_overflow_rows
        self.owner = {}  # affinity key -> shard index
        self.placed = [0] * shards
        self.seq = 0
        self.affinity_hits = 0
        self.load_routed = 0
        self.imbalance_max = 0

    def place(self, prompt, statuses):
        """statuses[i] = (live_rows, free_pages) of shard i. Returns
        (shard, memo)."""
        assert len(statuses) == self.shards
        memo = []
        hasher_update(memo, prompt, self.bs)
        n = min(self.affinity_blocks, len(memo))
        key = memo[n - 1] if n else None
        if self.policy == ROUND_ROBIN:
            shard = self.seq % self.shards
        else:
            shard = self.place_affinity(key, statuses)
        self.placed[shard] += 1
        self.imbalance_max = max(self.imbalance_max,
                                 max(self.placed) - min(self.placed))
        self.seq += 1
        return shard, memo

    def place_affinity(self, key, statuses):
        if key is not None and key in self.owner:
            owner = self.owner[key]
            min_rows = min(s[0] for s in statuses)
            if statuses[owner][0] <= min_rows + self.overflow:
                self.affinity_hits += 1
                return owner
        shard = min(range(self.shards),
                    key=lambda i: (statuses[i][0], -statuses[i][1],
                                   self.placed[i], i))
        if key is not None:
            self.owner[key] = shard
        self.load_routed += 1
        return shard


class AdmissionController:
    """admission.rs AdmissionController — the same pure state machine:
    the tenant bucket is checked before the queue cap, a queue-full shed
    spends no token, dequeue ticks refill every bucket (capped at the
    burst), and counting stays active even with both knobs off (0)."""

    def __init__(self, queue_cap, tenant_burst, tenant_refill):
        self.queue_cap = queue_cap
        self.burst = tenant_burst
        self.refill = tenant_refill
        self.depth = 0
        self.buckets = {}  # tenant -> remaining tokens (lazily full)
        self.admitted = 0
        self.shed = 0
        self.shed_by_tenant = {}
        self.peak = 0

    def offer(self, tenant):
        """None admits (the caller owes one on_dequeue); otherwise the
        shed reason's wire spelling."""
        if self.burst > 0:
            if tenant not in self.buckets:
                self.buckets[tenant] = self.burst
            if self.buckets[tenant] == 0:
                return self._shed(tenant, "tenant_rate_limited")
        if self.queue_cap > 0 and self.depth >= self.queue_cap:
            return self._shed(tenant, "queue_full")
        if self.burst > 0:
            self.buckets[tenant] -= 1
        self.depth += 1
        self.admitted += 1
        self.peak = max(self.peak, self.depth)
        return None

    def _shed(self, tenant, reason):
        self.shed += 1
        self.shed_by_tenant[tenant] = self.shed_by_tenant.get(tenant, 0) + 1
        return reason

    def on_dequeue(self):
        self.depth = max(self.depth - 1, 0)
        if self.burst > 0 and self.refill > 0:
            for t in self.buckets:
                self.buckets[t] = min(self.buckets[t] + self.refill,
                                      self.burst)

    def export_into(self, fp):
        fp["admitted_requests"] = self.admitted
        fp["shed_requests"] = self.shed
        for t in sorted(self.shed_by_tenant):
            fp["shed_by_tenant:%s" % t] = self.shed_by_tenant[t]
        fp["intake_queue_peak"] = self.peak


# ---------------------------------------------------------------------------
# Bench harness (bench.rs)
# ---------------------------------------------------------------------------

SCENARIOS = ["prefill_heavy", "decode_heavy", "mixed_poisson", "prefix_replay",
             "parallel_sampling", "beam_search", "beam_early_stop",
             "preemption_pressure", "long_context_stall", "multi_tenant_storm",
             "sharded_affinity", "failover_replay", "server_replay",
             "admission_storm"]

STEPS_PER_S = 25.0
SCHEMA_VERSION = 1


def bench_config(name, policy=DECODE_FIRST):
    cfg = EngineConfig()
    cfg.sched_policy = policy
    if name == "long_context_stall":
        cfg.max_prefill_tokens_per_step = 32
    elif name == "multi_tenant_storm":
        cfg.tenant_weights = {"acme": 4, "bligh": 2, "corto": 1}
    return cfg


def run_all(engine, reqs):
    for r in reqs:
        engine.add_group(r.prompt, r.sampling, r.max_new, r.priority, r.tenant)
    engine.run_to_completion()


def run_arrivals(engine, arrivals):
    nxt = 0
    step_no = 0
    while True:
        while nxt < len(arrivals) and arrivals[nxt][0] <= step_no:
            r = arrivals[nxt][1]
            engine.add_group(r.prompt, r.sampling, r.max_new, r.priority, r.tenant)
            nxt += 1
        if nxt >= len(arrivals) and not engine.sched.has_unfinished():
            return
        if engine.step() is None:
            if engine.sched.has_unfinished():
                raise RuntimeError("engine stuck with work pending")
            step_no = arrivals[nxt][0]
        else:
            step_no += 1


def merge_fingerprints(fps):
    """Fingerprint::merge — sum counters key-wise across shards."""
    out = OrderedDict()
    for fp in fps:
        for k, v in fp.items():
            out[k] = out.get(k, 0) + v
    return out


def journal_line(seq, shard, step, prompt, max_new, tenant="default"):
    """journal.rs JournalEntry::serialize for default (greedy) sampling:
    fixed field order, no whitespace, floats as 16-hex f64 bit patterns.
    `journal_bytes` is a gated counter, so every line must be the exact
    byte length the Rust dispatcher appends."""
    bits = "%016x" % f64_bits(0.0)
    return ('{"seq":%d,"shard":%d,"step":%d,"prompt":[%s],"max_new":%d,'
            '"n":1,"seed":0,"temp_bits":"%s","beam_width":0,'
            '"length_penalty_bits":"%s","early_stopping":false,'
            '"stop_token_ids":[],"stop_sequences":[],'
            '"priority":"interactive","tenant":"%s"}'
            % (seq, shard, step,
               ",".join(str(t) for t in prompt), max_new, bits, bits, tenant))


def sharded_affinity_waves(families, shared_prefix, tail, waves, rng):
    """workload.rs ShardedAffinity::waves — family prefixes drawn once up
    front, then one request per family per wave, in family order."""
    prefixes = [rng.tokens(shared_prefix) for _ in range(families)]
    out = []
    for _ in range(waves):
        out.append([prefix + rng.tokens(max(tail, 1))
                    for prefix in prefixes])
    return out


def run_sharded_affinity():
    """bench.rs run_sharded_affinity — a two-shard tier driven through
    the router, run once per policy over the byte-identical admission
    sequence; gates on the merged fingerprint plus the rr_* proof
    counters (affinity must strictly beat round-robin)."""
    shards, waves, families = 2, 4, 3

    def run_tier(policy):
        router = Router(shards, policy, BLOCK_SIZE)
        engines = [Engine(bench_config("sharded_affinity"))
                   for _ in range(shards)]
        for wave in sharded_affinity_waves(families, 48, 6, waves, Rng(53)):
            for prompt in wave:
                statuses = [(e.live_rows(), e.kv.free_pages())
                            for e in engines]
                shard, memo = router.place(prompt, statuses)
                engines[shard].add_group_routed(
                    prompt, SamplingParams.greedy(), 4, memo)
            # each wave drains shard-by-shard, like the Rust scenario
            for e in engines:
                e.run_to_completion()
        return engines, router

    engines, router = run_tier(AFFINITY)
    rr_engines, _ = run_tier(ROUND_ROBIN)
    fp = merge_fingerprints([fingerprint(e.m) for e in engines])
    rr = merge_fingerprints([fingerprint(e.m) for e in rr_engines])
    assert fp["prefix_hit_tokens"] > rr["prefix_hit_tokens"], \
        "affinity must beat round-robin on prefix hits"
    assert fp["pages_allocated"] < rr["pages_allocated"], \
        "affinity must beat round-robin on pages"
    fp["router_affinity_hits"] = router.affinity_hits
    fp["router_load_routed"] = router.load_routed
    fp["shard_imbalance_max"] = router.imbalance_max
    fp["rr_prefix_hit_tokens"] = rr["prefix_hit_tokens"]
    fp["rr_pages_allocated"] = rr["pages_allocated"]
    return fp, waves * families


def run_failover_replay():
    """bench.rs run_failover_replay — the SimTier kill/replay harness
    reduces analytically: the faulted run's merged fingerprint equals the
    crash-free run's by construction (the replacement engine replays the
    journal at the recorded admission steps, reproducing the dead
    shard's exact trajectory), so the port runs the clean two-shard tier
    once and derives the recovery counters from per-wave bookkeeping:

    * the kill lands at `horizon // 2` of shard 0's crash-free step
      count, which falls in the first wave whose shard-0 drain performs
      a dispatch check at or past that step;
    * every shard-0 journal entry admitted up to and including that wave
      is replayed (`replayed_groups`);
    * replay steps the replacement to the *last* replayed entry's
      admission step, so `replayed_tokens` is shard 0's cumulative
      generated-token count after the preceding wave;
    * `journal_bytes` sums the canonical line bytes of every admission
      on both shards (the journal is append-only through the fault)."""
    shards, waves, families = 2, 3, 3
    router = Router(shards, AFFINITY, BLOCK_SIZE)
    engines = [Engine(bench_config("failover_replay")) for _ in range(shards)]
    seq = 0
    entries = []      # (shard, wave) per admission, in admission order
    shard0 = []       # (cumulative steps, cumulative generated) per wave
    journal_bytes = 0
    for w, wave in enumerate(
            sharded_affinity_waves(families, 48, 6, waves, Rng(61)), 1):
        for prompt in wave:
            statuses = [(e.live_rows(), e.kv.free_pages()) for e in engines]
            shard, memo = router.place(prompt, statuses)
            seq += 1
            line = journal_line(seq, shard, engines[shard].m["steps"],
                                prompt, 4)
            journal_bytes += len(line) + 1
            entries.append((shard, w))
            engines[shard].add_group_routed(
                prompt, SamplingParams.greedy(), 4, memo)
        for e in engines:
            e.run_to_completion()
        shard0.append((engines[0].m["steps"],
                       engines[0].m["generated_tokens"]))
    horizon = shard0[-1][0]
    assert horizon >= 2, "failover_replay workload too small"
    kill = horizon // 2
    # SimTier::drain checks the kill before each dispatch: wave v checks
    # at steps S_{v-1}..S_v-1 when shard 0 holds work, so the kill fires
    # in the first wave with S_v > kill that advanced shard 0 at all
    kill_wave = prev = None
    for w, (s, _) in enumerate(shard0, 1):
        if s > kill and s != prev:
            kill_wave = w
            break
        prev = s
    assert kill_wave is not None, "kill landed outside the storm"
    replayed_groups = sum(1 for (shard, w) in entries
                          if shard == 0 and w <= kill_wave)
    assert replayed_groups > 0, "no shard-0 admissions before the kill"
    last_wave = max(w for (shard, w) in entries
                    if shard == 0 and w <= kill_wave)
    replayed_tokens = shard0[last_wave - 2][1] if last_wave >= 2 else 0
    fp = merge_fingerprints([fingerprint(e.m) for e in engines])
    fp["router_affinity_hits"] = router.affinity_hits
    fp["router_load_routed"] = router.load_routed
    fp["shard_imbalance_max"] = router.imbalance_max
    fp["shard_restarts"] = 1
    fp["replayed_groups"] = replayed_groups
    fp["replayed_tokens"] = replayed_tokens
    fp["journal_bytes"] = journal_bytes
    return fp, waves * families


def run_server_replay():
    """bench.rs run_server_replay — the lockstep TCP replay reduces to:
    one single-shard tier, each request placed through the router (memo
    seeded into the engine) and drained to idle by the client's `run`
    command before the next submit. The fingerprint is the server's
    merged `metrics` snapshot: engine counters + router counters + the
    recovery counters (no fault fires, so the restart/replay counters
    are zero and `journal_bytes` counts the six admissions the
    dispatcher journaled before forwarding)."""
    n_requests = 6
    engine = Engine(bench_config("server_replay"))
    router = Router(1, AFFINITY, BLOCK_SIZE)
    rng = Rng(41)
    journal_bytes = 0
    for seq in range(1, n_requests + 1):
        ln = rng.range(8, 32)
        prompt = rng.tokens(ln)
        shard, memo = router.place(
            prompt, [(engine.live_rows(), engine.kv.free_pages())])
        assert shard == 0
        line = journal_line(seq, 0, engine.m["steps"], prompt, 12)
        journal_bytes += len(line) + 1
        engine.add_group_routed(prompt, SamplingParams.greedy(), 12, memo)
        engine.run_to_completion()
    fp = fingerprint(engine.m)
    fp["router_affinity_hits"] = router.affinity_hits
    fp["router_load_routed"] = router.load_routed
    fp["shard_imbalance_max"] = router.imbalance_max
    fp["shard_restarts"] = 0
    fp["replayed_groups"] = 0
    fp["replayed_tokens"] = 0
    fp["journal_bytes"] = journal_bytes
    # admission counters: nothing sheds, and each lockstep submit is
    # drained by its own `run` before the next one arrives (peak 1)
    fp["admitted_requests"] = n_requests
    fp["shed_requests"] = 0
    fp["intake_queue_peak"] = 1
    return fp, n_requests


def admission_storm_requests(rng):
    """workload.rs AdmissionStorm::requests for the bench plan: 15
    round-robin submits across three tenants, one rng.range + rng.tokens
    pair per request, in request order."""
    tenants = ["acme", "bligh", "corto"]
    out = []
    for i in range(15):
        ln = rng.range(8, 24)
        out.append(Request(rng.tokens(ln), SamplingParams.greedy(), 6,
                           INTERACTIVE, tenants[i % 3]))
    return out


def run_admission_storm():
    """bench.rs run_admission_storm — the lockstep TCP storm reduces to:
    offer all 15 submits to the controller (in lockstep the whole burst
    is offered before any dequeue), then drain the admitted subset
    through the two-shard router exactly like the dispatcher's `run`
    boundary (one dequeue tick + one journal line + one placement per
    request, all at engine step 0), and run each shard to completion in
    shard order. Shed requests spend no global seq and touch nothing
    downstream, so the merged fingerprint is the admitted subset's plus
    the controller's exported admission counters."""
    reqs = admission_storm_requests(Rng(47))
    ctrl = AdmissionController(7, 3, 1)
    admitted = [r for r in reqs if ctrl.offer(r.tenant) is None]
    shards = 2
    router = Router(shards, AFFINITY, BLOCK_SIZE)
    engines = [Engine(bench_config("admission_storm"))
               for _ in range(shards)]
    journal_bytes = 0
    for seq, r in enumerate(admitted, 1):
        ctrl.on_dequeue()
        statuses = [(e.live_rows(), e.kv.free_pages()) for e in engines]
        shard, memo = router.place(r.prompt, statuses)
        line = journal_line(seq, shard, engines[shard].m["steps"],
                            r.prompt, r.max_new, tenant=r.tenant)
        journal_bytes += len(line) + 1
        engines[shard].add_group_routed(r.prompt, SamplingParams.greedy(),
                                        r.max_new, memo, tenant=r.tenant)
    for e in engines:
        e.run_to_completion()
    fp = merge_fingerprints([fingerprint(e.m) for e in engines])
    fp["router_affinity_hits"] = router.affinity_hits
    fp["router_load_routed"] = router.load_routed
    fp["shard_imbalance_max"] = router.imbalance_max
    fp["shard_restarts"] = 0
    fp["replayed_groups"] = 0
    fp["replayed_tokens"] = 0
    fp["journal_bytes"] = journal_bytes
    ctrl.export_into(fp)
    return fp, len(reqs)


def run_scenario(name, policy=DECODE_FIRST):
    if name == "sharded_affinity":
        return run_sharded_affinity()
    if name == "failover_replay":
        return run_failover_replay()
    if name == "server_replay":
        return run_server_replay()
    if name == "admission_storm":
        return run_admission_storm()
    engine = Engine(bench_config(name, policy))
    engine.warmup()
    if name == "prefill_heavy":
        rng = Rng(11)
        for _ in range(8):
            ln = rng.range(48, 80)
            engine.add_group(rng.tokens(ln), SamplingParams.greedy(), 2)
        engine.run_to_completion()
        requests = 8
    elif name == "decode_heavy":
        rng = Rng(13)
        for _ in range(6):
            engine.add_group(rng.tokens(8), SamplingParams.greedy(), 24)
        engine.run_to_completion()
        requests = 6
    elif name == "mixed_poisson":
        rng = Rng(31)
        events = arrival_process_sample(rng, 12.0, 8, 48, 4, 16, 10)
        arrivals = [(int(at * STEPS_PER_S),
                     Request(rng.tokens(plen), SamplingParams.greedy(), mnew))
                    for (at, plen, mnew) in events]
        run_arrivals(engine, arrivals)
        requests = 10
    elif name == "prefix_replay":
        run_all(engine, prefix_replay_wave(64, 6, 4, 21, 4))
        run_all(engine, prefix_replay_wave(64, 6, 4, 21, 4))
        requests = 8
    elif name == "parallel_sampling":
        run_all(engine, best_of_n_requests(4, 32, 8, 6, [], 3, Rng(5)))
        requests = 3
    elif name == "beam_search":
        run_all(engine, beam_bench_requests(False, 3, Rng(9)))
        requests = 3
    elif name == "beam_early_stop":
        run_all(engine, beam_bench_requests(True, 3, Rng(9)))
        requests = 3
    elif name == "preemption_pressure":
        rng = Rng(17)
        for _ in range(4):
            engine.add_group(rng.tokens(40), SamplingParams.greedy(), 24)
        engine.run_to_completion()
        requests = 4
    elif name == "long_context_stall":
        run_arrivals(engine, long_context_stall_arrivals(Rng(37)))
        requests = 4
    elif name == "multi_tenant_storm":
        run_all(engine, multi_tenant_storm_requests(2, Rng(43)))
        requests = 12
    else:
        raise ValueError(name)
    return fingerprint(engine.m), requests


def fingerprint(m):
    fp = OrderedDict()
    fp["engine_steps"] = m["steps"]
    for k in ("generated_tokens", "prompt_tokens", "preemptions",
              "self_preemptions", "groups_finished", "pages_allocated",
              "forked_pages", "cow_copies", "prefix_hit_tokens",
              "prefix_lookup_tokens", "prefix_evictions", "stop_finishes",
              "beam_forks", "beam_prunes", "beam_pruned_pages",
              "beam_finished_hyps", "beam_early_terminations", "token_events",
              "decode_stall_steps", "max_decode_gap_steps",
              "prefill_chunk_deferrals", "arena_reuses", "arena_grows",
              "prefix_hash_skips", "cancelled_groups"):
        fp[k] = m[k]
    for tenant in sorted(m["wfq_admitted_tokens"]):
        fp["wfq_admitted_tokens:%s" % tenant] = m["wfq_admitted_tokens"][tenant]
    return fp


def zero_snapshot():
    return OrderedDict([("count", 0), ("mean", 0.0), ("p50", 0.0), ("p95", 0.0),
                        ("p99", 0.0), ("min", 0.0), ("max", 0.0)])


def zero_phases():
    """Per-phase step profiler block (bench.rs PhaseProfile): the port has
    no wall clock, so like the timings it emits zeroed snapshots — compare
    never reads phases, only the fingerprint gates."""
    return OrderedDict([(k, zero_snapshot())
                        for k in ("schedule_us", "build_us", "stage_us",
                                  "dispatch_us", "output_us")])


def scenario_result(name, fp, requests):
    return OrderedDict([
        ("name", name),
        ("deterministic", True),
        ("requests", requests),
        ("fingerprint", fp),
        ("phases", zero_phases()),
        ("timings", OrderedDict([
            ("wall_s", 0.0),
            ("throughput_tok_s", 0.0),
            ("ttft_ms", zero_snapshot()),
            ("inter_token_ms", zero_snapshot()),
            ("request_latency_ms", zero_snapshot()),
        ])),
    ])


def generate(out_path):
    report = OrderedDict([
        ("schema_version", SCHEMA_VERSION),
        ("label", "baseline"),
        ("model", "tiny"),
        ("scenarios", []),
    ])
    for name in SCENARIOS:
        fp, requests = run_scenario(name)
        report["scenarios"].append(scenario_result(name, fp, requests))
        print("  %-20s steps=%-4d gen=%-4d prompt=%-4d" %
              (name, fp["engine_steps"], fp["generated_tokens"],
               fp["prompt_tokens"]))
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print("wrote %s" % out_path)


def validate(baseline_path, policy):
    """Replay the matrix and diff counters against a checked-in baseline.

    Use --legacy to model the pre-SLO scheduler (how this port was first
    cross-checked against the baseline the old Rust engine produced)."""
    base = json.load(open(baseline_path))
    failures = 0
    for sc in base["scenarios"]:
        name = sc["name"]
        got, requests = run_scenario(name, policy=policy)
        want = sc["fingerprint"]
        diffs = []
        for k, v in want.items():
            if got.get(k, 0) != v:
                diffs.append("%s: want %s got %s" % (k, v, got.get(k, 0)))
        if requests != sc["requests"]:
            diffs.append("requests: want %s got %s" % (sc["requests"], requests))
        status = "ok" if not diffs else "FAIL"
        print("%-20s %s" % (name, status))
        for d in diffs:
            print("    " + d)
        failures += bool(diffs)
    if failures:
        print("%d scenario(s) diverged" % failures)
        return 1
    print("port matches the checked-in baseline")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate", action="store_true",
                    help="replay the matrix and diff vs the checked-in baseline")
    ap.add_argument("--legacy", action="store_true",
                    help="validate with the pre-SLO LegacyMixed policy")
    ap.add_argument("--baseline", default=os.path.join(REPO, "BENCH_baseline.json"))
    ap.add_argument("--out", default=os.path.join(REPO, "BENCH_baseline.json"))
    args = ap.parse_args()
    assert WSEED == 0x5E5A8215F9C06550, hex(WSEED)
    if args.validate:
        sys.exit(validate(args.baseline,
                          LEGACY_MIXED if args.legacy else DECODE_FIRST))
    generate(args.out)


if __name__ == "__main__":
    main()
