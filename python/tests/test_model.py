"""L2 correctness: the paged transformer step vs. a dense reference.

A mini engine (mirroring the Rust metadata builder contract) drives
``model_step`` prefill + decode over the paged KV cache; results must match
a plain dense-causal-attention forward pass token for token — this pins
down RoPE positions, cache scatter ordering, GQA mapping, and greedy
sampling all at once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import Bucket, KernelConfig, ModelConfig, cdiv
from compile.model import Params, init_params, model_step, rms_norm, rope

MODEL = ModelConfig(num_layers=2, hidden_size=64, num_q_heads=4,
                    num_kv_heads=2, head_size=16, intermediate_size=128,
                    vocab_size=128, max_model_len=128)


def dense_forward(params: Params, tokens: np.ndarray,
                  model: ModelConfig) -> np.ndarray:
    """Reference: full dense causal forward, returns logits [n, vocab]."""
    n = len(tokens)
    positions = jnp.arange(n)
    x = params.embed[jnp.asarray(tokens)]
    H, KV, D = model.num_q_heads, model.num_kv_heads, model.head_size
    qpk = model.queries_per_kv
    for l in range(model.num_layers):
        h = rms_norm(x, params.attn_norm[l])
        q = rope((h @ params.wq[l]).reshape(n, H, D), positions,
                 model.rope_theta)
        k = rope((h @ params.wk[l]).reshape(n, KV, D), positions,
                 model.rope_theta)
        v = (h @ params.wv[l]).reshape(n, KV, D)
        k_full = jnp.repeat(k, qpk, axis=1)      # GQA: share KV heads
        v_full = jnp.repeat(v, qpk, axis=1)
        s = jnp.einsum("qhd,khd->hqk", q, k_full) / np.sqrt(D)
        mask = np.tril(np.ones((n, n), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("hqk,khd->qhd", p, v_full).reshape(n, H * D)
        x = x + attn @ params.wo[l]
        h = rms_norm(x, params.mlp_norm[l])
        x = x + (jax.nn.silu(h @ params.w_gate[l]) * (h @ params.w_up[l])
                 ) @ params.w_down[l]
    x = rms_norm(x, params.final_norm)
    return np.asarray(x @ params.lm_head)


class MiniEngine:
    """Python mirror of the Rust metadata builder + paged cache, driving
    ``model_step`` one batch at a time. Physical page 0 is scratch."""

    def __init__(self, model: ModelConfig, cfg: KernelConfig,
                 bucket: Bucket, params: Params):
        self.model, self.cfg, self.bucket, self.params = model, cfg, bucket, params
        L, KV, D = model.num_layers, model.num_kv_heads, model.head_size
        self.kv_caches = jnp.zeros((L, 2, bucket.num_slots, KV, D),
                                   jnp.float32)
        self.num_pages = bucket.num_slots // cfg.block_size
        self.free_pages = list(range(1, self.num_pages))
        self.tables: dict[int, list[int]] = {}
        self.lens: dict[int, int] = {}           # tokens in cache per seq

    def _ensure_blocks(self, sid: int, new_len: int):
        tbl = self.tables.setdefault(sid, [])
        need = cdiv(new_len, self.cfg.block_size)
        while len(tbl) < need:
            tbl.append(self.free_pages.pop(0))

    def step(self, batch: list[tuple[int, list[int]]]):
        """batch: [(seq_id, new_tokens)]; returns {seq_id: next_token}."""
        bq = self.cfg.block_q if self.cfg.variant in ("qblock", "static",
                                                      "flash") else 1
        B, M = self.bucket, self.model
        bs = self.cfg.block_size
        token_ids = np.zeros(B.max_tokens, np.int32)
        positions = np.zeros(B.max_tokens, np.int32)
        slot_map = np.zeros(B.max_tokens, np.int32)   # scratch page 0
        block_table = np.zeros((B.max_seqs, B.max_blocks), np.int32)
        seq_lens = np.zeros(B.max_seqs, np.int32)
        ctx_lens = np.zeros(B.max_seqs, np.int32)
        starts = np.zeros(B.max_seqs + 1, np.int32)
        last_idx = np.zeros(B.max_seqs, np.int32)

        t = 0
        for i, (sid, new) in enumerate(batch):
            ctx = self.lens.get(sid, 0)
            total = ctx + len(new)
            self._ensure_blocks(sid, total)
            tbl = self.tables[sid]
            block_table[i, :len(tbl)] = tbl
            seq_lens[i], ctx_lens[i], starts[i] = total, ctx, t
            for j, tok in enumerate(new):
                pos = ctx + j
                token_ids[t + j] = tok
                positions[t + j] = pos
                slot_map[t + j] = tbl[pos // bs] * bs + pos % bs
            last_idx[i] = t + len(new) - 1
            t += cdiv(len(new), bq) * bq
            self.lens[sid] = total
        starts[len(batch):] = t
        assert t <= B.max_tokens

        out, self.kv_caches = jax.jit(
            lambda *ops: model_step(self.params, *ops, cfg=self.cfg,
                                    model=M, bucket=B)
        )(jnp.asarray(token_ids), jnp.asarray(positions),
          self.kv_caches, jnp.asarray(block_table),
          jnp.asarray(seq_lens), jnp.asarray(ctx_lens), jnp.asarray(starts),
          jnp.asarray(slot_map), jnp.asarray(last_idx))
        return {sid: int(out[i]) for i, (sid, _) in enumerate(batch)}


def make_engine(variant="qblock", block_q=4, max_seqs=2, max_tokens=32,
                seed=7):
    cfg = KernelConfig(variant=variant, block_size=8, tile_n=8,
                       block_q=block_q, num_segments=4, static_programs=4,
                       use_dot=variant != "naive")
    if variant == "parts":          # decode-only contract: one token/seq
        max_tokens = max_seqs
    max_blocks = MODEL.max_model_len // cfg.block_size
    bucket = Bucket(max_seqs=max_seqs, max_tokens=max_tokens,
                    max_blocks=max_blocks,
                    num_slots=(max_seqs * max_blocks + 1) * cfg.block_size)
    params = init_params(MODEL, seed=seed)
    return MiniEngine(MODEL, cfg, bucket, params)


def greedy_ref(params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits = dense_forward(params, np.array(toks), MODEL)
        toks.append(int(np.argmax(logits[-1])))
    return toks[len(prompt):]


PROMPT = [3, 17, 42, 7, 99, 21, 5, 64, 11, 30, 2, 77, 8]


class TestModelStep:
    def test_prefill_matches_dense(self):
        eng = make_engine()
        out = eng.step([(0, PROMPT)])
        logits = dense_forward(eng.params, np.array(PROMPT), MODEL)
        assert out[0] == int(np.argmax(logits[-1]))

    def test_decode_continuation_matches_dense(self):
        eng = make_engine()
        ref = greedy_ref(eng.params, PROMPT, 4)
        got = [eng.step([(0, PROMPT)])[0]]
        for _ in range(3):
            got.append(eng.step([(0, [got[-1]])])[0])
        assert got == ref

    def test_batched_equals_individual(self):
        p2 = [9, 1, 55, 3, 88, 14]
        eng_a = make_engine(max_seqs=1)
        eng_b = make_engine(max_seqs=1)
        solo = [eng_a.step([(0, PROMPT)])[0], eng_b.step([(0, p2)])[0]]
        eng = make_engine(max_seqs=2, max_tokens=32)
        both = eng.step([(0, PROMPT), (1, p2)])
        assert [both[0], both[1]] == solo

    @pytest.mark.parametrize("variant,block_q",
                             [("naive", 1), ("static", 4), ("flash", 4)])
    def test_variants_agree_on_prefill(self, variant, block_q):
        base = make_engine("qblock").step([(0, PROMPT)])[0]
        assert make_engine(variant, block_q).step([(0, PROMPT)])[0] == base

    @pytest.mark.parametrize("variant", ["naive", "parts", "static", "flash"])
    def test_variants_agree_on_decode(self, variant):
        ref_eng = make_engine("qblock", block_q=1)
        first = ref_eng.step([(0, PROMPT)])[0]
        ref_next = ref_eng.step([(0, [first])])[0]
        eng = make_engine(variant, block_q=1)
        f2 = eng.step([(0, PROMPT)]) if variant not in ("parts",) else None
        if variant == "parts":
            # parts is decode-only: prefill with qblock, decode with parts
            pre = make_engine("qblock", block_q=1)
            first2 = pre.step([(0, PROMPT)])[0]
            eng.kv_caches = pre.kv_caches
            eng.tables, eng.lens = pre.tables, pre.lens
            eng.free_pages = pre.free_pages
            assert first2 == first
            assert eng.step([(0, [first2])])[0] == ref_next
        else:
            assert f2[0] == first
            assert eng.step([(0, [first])])[0] == ref_next

    def test_chunked_prefill_equals_single_shot(self):
        eng1 = make_engine()
        tok1 = eng1.step([(0, PROMPT)])[0]
        eng2 = make_engine()
        eng2.step([(0, PROMPT[:8])])
        tok2 = eng2.step([(0, PROMPT[8:])])[0]
        assert tok1 == tok2
