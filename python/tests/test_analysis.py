"""Pins the qualitative claims of the analytic kernel model (DESIGN §7):
the quantities that drive the paper's GPU results but cannot appear in
interpret-mode wallclock."""

from __future__ import annotations

import pytest

from compile.analysis import (ScenarioShape, VMEM_BYTES, model_kernel,
                              mxu_utilization_estimate)
from compile.aot import KERNEL_GEOM, PROFILES
from compile.config import KernelConfig

GEOM = KERNEL_GEOM
DECODE = ScenarioShape(num_seqs=2, seq_len=2048, query_len=1)
PREFILL = ScenarioShape(num_seqs=2, seq_len=512, query_len=512)


def cfg(variant, **kw):
    base = dict(block_size=16, tile_n=16, block_q=1, num_segments=8,
                static_programs=16, use_dot=False)
    base.update(kw)
    return KernelConfig(variant=variant, **base)


class TestRedundancy:
    def test_naive_loads_qpk_times_more_than_qblock(self):
        # the §4.4 claim: Q-Block loads each K/V tile once per KV head,
        # naive once per query head.
        n = model_kernel(cfg("naive"), GEOM, DECODE)
        q = model_kernel(cfg("qblock"), GEOM, DECODE)
        assert n.hbm_bytes == GEOM.queries_per_kv * q.hbm_bytes
        assert n.flops == q.flops  # same math, more traffic

    def test_qblock_raises_arithmetic_intensity(self):
        n = model_kernel(cfg("naive"), GEOM, PREFILL)
        q = model_kernel(cfg("qblock", block_q=16), GEOM, PREFILL)
        assert q.arithmetic_intensity > 2 * n.arithmetic_intensity


class TestParallelism:
    def test_parts_divides_critical_path(self):
        # §4.5: segments shorten the serial tile chain for long decodes
        q = model_kernel(cfg("qblock"), GEOM, DECODE)
        p8 = model_kernel(cfg("parts", num_segments=8), GEOM, DECODE)
        assert p8.critical_path_tiles < q.critical_path_tiles / 4
        assert p8.instances == 8 * GEOM.num_kv_heads * DECODE.num_seqs

    def test_more_segments_more_instances_shorter_path(self):
        prev_path, prev_inst = None, None
        for s in (1, 2, 4, 8, 16):
            m = model_kernel(cfg("parts", num_segments=s), GEOM, DECODE)
            if prev_path is not None:
                assert m.critical_path_tiles <= prev_path
                assert m.instances > prev_inst
            prev_path, prev_inst = m.critical_path_tiles, m.instances

    def test_static_grid_bounds_instances(self):
        # §4.7: instance count independent of the batch
        small = ScenarioShape(1, 128, 1)
        big = ScenarioShape(8, 128, 1)
        a = model_kernel(cfg("static", static_programs=16), GEOM, small)
        b = model_kernel(cfg("static", static_programs=16), GEOM, big)
        assert a.instances == b.instances == 16 * GEOM.num_kv_heads

    def test_prefill_has_enough_instances_without_segments(self):
        # §4.5: "this limitation does not apply to prefill attention"
        q = model_kernel(cfg("qblock", block_q=16), GEOM, PREFILL)
        d = model_kernel(cfg("qblock"), GEOM,
                         ScenarioShape(1, 2048, 1))
        assert q.instances > 8 * d.instances


class TestVmemBudget:
    @pytest.mark.parametrize("profile", ["default", "bench"])
    def test_every_exported_config_fits_vmem(self, profile):
        arts, _ = PROFILES[profile]()
        for a in arts:
            if a.kind != "kernel":
                continue
            m = model_kernel(a.cfg, GEOM, DECODE if a.cfg.block_q == 1
                             else PREFILL)
            assert m.vmem_bytes < VMEM_BYTES, a.name

    def test_vmem_grows_with_tile_and_block(self):
        small = model_kernel(cfg("qblock"), GEOM, PREFILL).vmem_bytes
        big = model_kernel(cfg("qblock", tile_n=64, block_q=16),
                           GEOM, PREFILL).vmem_bytes
        assert big > 4 * small


class TestMxu:
    def test_elementwise_path_never_uses_mxu(self):
        assert mxu_utilization_estimate(cfg("qblock"), GEOM) == 0.0

    def test_dot_path_utilization_scales_with_tiles(self):
        lo = mxu_utilization_estimate(cfg("qblock", use_dot=True), GEOM)
        hi = mxu_utilization_estimate(
            cfg("qblock", use_dot=True, tile_n=128, block_q=32), GEOM)
        assert 0.0 < lo < hi <= 1.0
        # block_q=32 × qpk=4 = 128 rows, tile 128 → full MXU occupancy
        assert hi == 1.0
