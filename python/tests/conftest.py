"""Shared fixtures: synthetic paged-cache scenario builder.

A *scenario* is a batch of sequences, each with a context length (tokens
already in the KV cache) and a query length (new tokens this step), laid
out exactly the way the Rust metadata builder (§6.1) lays them out:

  * packed query tensor with each sequence's region aligned to ``block_q``,
  * KV pages assigned through a shuffled block table (pages are
    deliberately non-contiguous to exercise the indirection),
  * seq_lens / ctx_lens / query_start_loc metadata vectors padded to the
    bucket's ``max_seqs``.
"""

from __future__ import annotations

import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.config import Bucket, KernelConfig, ModelConfig, cdiv  # noqa: E402


@dataclasses.dataclass
class Scenario:
    q: np.ndarray
    k_cache: np.ndarray
    v_cache: np.ndarray
    block_table: np.ndarray
    seq_lens: np.ndarray
    ctx_lens: np.ndarray
    query_start_loc: np.ndarray
    bucket: Bucket
    model: ModelConfig
    cfg: KernelConfig

    def operands(self):
        return (self.q, self.k_cache, self.v_cache, self.block_table,
                self.seq_lens, self.ctx_lens, self.query_start_loc)

    def valid_rows(self):
        """Indices of packed q rows that carry real query tokens."""
        rows = []
        for s in range(len(self.seq_lens)):
            q_len = int(self.seq_lens[s] - self.ctx_lens[s])
            t0 = int(self.query_start_loc[s])
            rows.extend(range(t0, t0 + q_len))
        return np.array(rows, dtype=np.int64)


def align(x: int, a: int) -> int:
    return cdiv(x, a) * a


def make_scenario(
    seqs: list[tuple[int, int]],       # (context_len, query_len) per seq
    cfg: KernelConfig,
    model: ModelConfig,
    *,
    bucket: Bucket | None = None,
    seed: int = 0,
) -> Scenario:
    rng = np.random.default_rng(seed)
    bs = cfg.block_size
    align_q = cfg.block_q if cfg.variant in ("qblock", "static", "flash") else 1

    total_aligned = sum(align(q, align_q) for _, q in seqs)
    max_len = max((c + q) for c, q in seqs)
    blocks_per_seq = [cdiv(c + q, bs) for c, q in seqs]

    if bucket is None:
        max_tokens = max(align(total_aligned, max(align_q, 1)), align_q)
        max_blocks = max(blocks_per_seq)
        num_blocks = sum(blocks_per_seq) + 2     # a couple of spare pages
        bucket = Bucket(max_seqs=len(seqs), max_tokens=max_tokens,
                        max_blocks=max_blocks, num_slots=num_blocks * bs)

    S, T = bucket.max_seqs, bucket.max_tokens
    H, KVH, D = model.num_q_heads, model.num_kv_heads, model.head_size
    assert len(seqs) <= S

    q = rng.standard_normal((T, H, D)).astype(np.float32)
    k_cache = rng.standard_normal((bucket.num_slots, KVH, D)).astype(np.float32)
    v_cache = rng.standard_normal((bucket.num_slots, KVH, D)).astype(np.float32)

    # Shuffled page assignment: sequences own disjoint random physical pages.
    num_pages = bucket.num_slots // bs
    perm = rng.permutation(num_pages)
    block_table = np.zeros((S, bucket.max_blocks), np.int32)
    next_page = 0
    for s, nb in enumerate(blocks_per_seq):
        assert nb <= bucket.max_blocks
        block_table[s, :nb] = perm[next_page:next_page + nb]
        next_page += nb

    seq_lens = np.zeros(S, np.int32)
    ctx_lens = np.zeros(S, np.int32)
    starts = np.zeros(S + 1, np.int32)
    t = 0
    for s, (c, ql) in enumerate(seqs):
        seq_lens[s] = c + ql
        ctx_lens[s] = c
        starts[s] = t
        t += align(ql, align_q)
    starts[len(seqs):] = t
    assert t <= T, f"scenario needs {t} tokens, bucket has {T}"

    return Scenario(q, k_cache, v_cache, block_table, seq_lens, ctx_lens,
                    starts, bucket, model, cfg)


@pytest.fixture
def tiny_model():
    return ModelConfig(num_layers=2, hidden_size=64, num_q_heads=4,
                       num_kv_heads=2, head_size=16, intermediate_size=128,
                       vocab_size=256, max_model_len=256)
