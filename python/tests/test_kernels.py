"""L1 correctness: every kernel variant vs. the pure-numpy oracle.

These are the paper's functional guarantees: identical results across the
naive, Q-Block, parallel-tiled-softmax, static-grid, and flash-baseline
kernels, for prefill, decode, and mixed batches, GQA/MQA/MHA mappings, and
tile sizes below/equal/above the KV page size (§4.6).
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from compile.config import Bucket, KernelConfig, ModelConfig
from compile.kernels import get_kernel
from compile.kernels.ref import paged_attention_ref
from conftest import make_scenario

MODEL = ModelConfig(num_layers=1, hidden_size=64, num_q_heads=4,
                    num_kv_heads=2, head_size=16, intermediate_size=128,
                    vocab_size=256, max_model_len=512)
MQA = ModelConfig(num_layers=1, hidden_size=64, num_q_heads=4,
                  num_kv_heads=1, head_size=16, intermediate_size=128,
                  vocab_size=256, max_model_len=512)
MHA = ModelConfig(num_layers=1, hidden_size=64, num_q_heads=4,
                  num_kv_heads=4, head_size=16, intermediate_size=128,
                  vocab_size=256, max_model_len=512)


def run_and_check(scn, atol=2e-5):
    kernel = get_kernel(scn.cfg)
    out = jax.jit(
        lambda *ops: kernel(*ops, cfg=scn.cfg, model=scn.model,
                            bucket=scn.bucket)
    )(*scn.operands())
    out = np.asarray(out)
    ref = paged_attention_ref(*scn.operands(), block_size=scn.cfg.block_size,
                              queries_per_kv=scn.model.queries_per_kv)
    rows = scn.valid_rows()
    np.testing.assert_allclose(out[rows], ref[rows], atol=atol, rtol=1e-4)


# ---------------------------------------------------------------- naive

class TestNaive:
    def test_single_decode(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(37, 1)], cfg, MODEL))

    def test_decode_batch(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(17, 1), (64, 1), (3, 1), (128, 1)],
                                    cfg, MODEL))

    def test_prefill(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(0, 29)], cfg, MODEL))

    def test_mixed_batch(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(0, 13), (40, 1), (5, 7)], cfg, MODEL))

    def test_chunked_prefill_continuation(self):
        # context > 0 AND query > 1: a chunked-prefill continuation step.
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(24, 9)], cfg, MODEL))

    def test_exact_page_boundary(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=False)
        run_and_check(make_scenario([(16, 8), (8, 8)], cfg, MODEL))

    def test_dot_path_matches(self):
        cfg = KernelConfig(variant="naive", block_size=8, tile_n=8,
                           block_q=1, use_dot=True)
        run_and_check(make_scenario([(11, 5), (30, 1)], cfg, MODEL))


# --------------------------------------------------------------- qblock

class TestQBlock:
    def test_prefill(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=4)
        run_and_check(make_scenario([(0, 30)], cfg, MODEL))

    def test_prefill_batch(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=4)
        run_and_check(make_scenario([(0, 30), (0, 7), (0, 16)], cfg, MODEL))

    def test_decode_batch(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=1)
        run_and_check(make_scenario([(33, 1), (8, 1), (100, 1)], cfg, MODEL))

    def test_mixed(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=4)
        run_and_check(make_scenario([(0, 19), (55, 1), (12, 6)], cfg, MODEL))

    def test_block_q_larger_than_query(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=16)
        run_and_check(make_scenario([(0, 5)], cfg, MODEL))

    def test_mqa(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=2)
        run_and_check(make_scenario([(0, 12), (21, 1)], cfg, MQA))

    def test_mha(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8, block_q=2)
        run_and_check(make_scenario([(0, 12), (21, 1)], cfg, MHA))

    def test_elementwise_path(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=8,
                           block_q=4, use_dot=False)
        run_and_check(make_scenario([(0, 10), (9, 3)], cfg, MODEL))


# --------------------------------------------- adjustable tile sizes (§4.6)

class TestFlexTiles:
    @pytest.mark.parametrize("tile_n", [4, 8, 16, 32])
    def test_qblock_tile_sweep(self, tile_n):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=tile_n,
                           block_q=4)
        run_and_check(make_scenario([(0, 27), (50, 1), (13, 6)], cfg, MODEL))

    @pytest.mark.parametrize("tile_n", [4, 8, 32])
    def test_parts_tile_sweep(self, tile_n):
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=tile_n,
                           block_q=1, num_segments=4)
        run_and_check(make_scenario([(61, 1), (15, 1)], cfg, MODEL))

    def test_non_pow2_total_length(self):
        cfg = KernelConfig(variant="qblock", block_size=8, tile_n=32, block_q=4)
        run_and_check(make_scenario([(0, 37)], cfg, MODEL))


# ------------------------------------------------ parallel tiled softmax

class TestParts:
    def test_single_long_decode(self):
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=8,
                           block_q=1, num_segments=4)
        run_and_check(make_scenario([(200, 1)], cfg, MODEL))

    def test_decode_batch(self):
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=8,
                           block_q=1, num_segments=4)
        run_and_check(make_scenario([(31, 1), (111, 1), (64, 1), (7, 1)],
                                    cfg, MODEL))

    @pytest.mark.parametrize("nseg", [1, 2, 8, 16])
    def test_segment_count_sweep(self, nseg):
        # merge must be exact for any segmentation, incl. empty segments
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=8,
                           block_q=1, num_segments=nseg)
        run_and_check(make_scenario([(90, 1), (5, 1)], cfg, MODEL))

    def test_more_segments_than_tiles(self):
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=8,
                           block_q=1, num_segments=16)
        run_and_check(make_scenario([(9, 1)], cfg, MODEL))

    def test_mqa(self):
        cfg = KernelConfig(variant="parts", block_size=8, tile_n=8,
                           block_q=1, num_segments=2)
        run_and_check(make_scenario([(44, 1)], cfg, MQA))


# ------------------------------------------------------ static launch grid

class TestStaticGrid:
    @pytest.mark.parametrize("programs", [1, 2, 8])
    def test_programs_sweep(self, programs):
        cfg = KernelConfig(variant="static", block_size=8, tile_n=8,
                           block_q=4, static_programs=programs)
        run_and_check(make_scenario([(0, 21), (30, 1), (4, 9)], cfg, MODEL))

    def test_more_programs_than_qblocks(self):
        cfg = KernelConfig(variant="static", block_size=8, tile_n=8,
                           block_q=4, static_programs=64)
        run_and_check(make_scenario([(0, 6)], cfg, MODEL))

    def test_matches_qblock_exactly(self):
        scn_args = [(0, 18), (25, 1), (7, 5)]
        cfg_s = KernelConfig(variant="static", block_size=8, tile_n=16,
                             block_q=4, static_programs=4)
        cfg_q = KernelConfig(variant="qblock", block_size=8, tile_n=16,
                             block_q=4)
        scn_s = make_scenario(scn_args, cfg_s, MODEL, seed=3)
        scn_q = make_scenario(scn_args, cfg_q, MODEL, seed=3)
        out_s = np.asarray(get_kernel(cfg_s)(
            *scn_s.operands(), cfg=cfg_s, model=MODEL, bucket=scn_s.bucket))
        out_q = np.asarray(get_kernel(cfg_q)(
            *scn_q.operands(), cfg=cfg_q, model=MODEL, bucket=scn_q.bucket))
        rows = scn_s.valid_rows()
        np.testing.assert_allclose(out_s[rows], out_q[rows], atol=1e-6)


# --------------------------------------------------------- flash baseline

class TestFlashBaseline:
    def test_prefill(self):
        cfg = KernelConfig(variant="flash", block_size=8, tile_n=16, block_q=4)
        run_and_check(make_scenario([(0, 30), (0, 9)], cfg, MODEL))

    def test_decode(self):
        cfg = KernelConfig(variant="flash", block_size=8, tile_n=8, block_q=1)
        run_and_check(make_scenario([(73, 1), (12, 1)], cfg, MODEL))

    def test_mixed(self):
        cfg = KernelConfig(variant="flash", block_size=8, tile_n=8, block_q=4)
        run_and_check(make_scenario([(0, 11), (40, 1)], cfg, MODEL))
