"""Property-based kernel sweeps (hypothesis): random geometries, batch
compositions, page sizes and tile sizes must all agree with the oracle.

These complement test_kernels.py's directed cases by searching the shape
space the paper's autotuner sweeps: block_size × tile_n × block_q ×
segments × GQA ratio × batch composition.
"""

from __future__ import annotations

import jax
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.config import KernelConfig, ModelConfig
from compile.kernels import get_kernel
from compile.kernels.ref import paged_attention_ref
from conftest import make_scenario

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def model_for(qpk: int, kv_heads: int, head: int) -> ModelConfig:
    return ModelConfig(num_layers=1, hidden_size=qpk * kv_heads * head,
                       num_q_heads=qpk * kv_heads, num_kv_heads=kv_heads,
                       head_size=head, intermediate_size=64,
                       vocab_size=128, max_model_len=1024)


def check(scn, atol=3e-5):
    kernel = get_kernel(scn.cfg)
    out = np.asarray(jax.jit(
        lambda *ops: kernel(*ops, cfg=scn.cfg, model=scn.model,
                            bucket=scn.bucket))(*scn.operands()))
    ref = paged_attention_ref(*scn.operands(), block_size=scn.cfg.block_size,
                              queries_per_kv=scn.model.queries_per_kv)
    rows = scn.valid_rows()
    np.testing.assert_allclose(out[rows], ref[rows], atol=atol, rtol=1e-4)


seq_strategy = st.lists(
    st.tuples(st.integers(0, 70), st.integers(1, 20)),
    min_size=1, max_size=4,
)


@settings(**SETTINGS)
@given(
    seqs=seq_strategy,
    block_size=st.sampled_from([4, 8, 16]),
    qpk=st.sampled_from([1, 2, 4]),
    kv_heads=st.sampled_from([1, 2]),
    head=st.sampled_from([8, 16]),
    use_dot=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_naive_matches_oracle(seqs, block_size, qpk, kv_heads, head,
                              use_dot, seed):
    cfg = KernelConfig(variant="naive", block_size=block_size,
                       tile_n=block_size, block_q=1, use_dot=use_dot)
    model = model_for(qpk, kv_heads, head)
    check(make_scenario(seqs, cfg, model, seed=seed))


@settings(**SETTINGS)
@given(
    seqs=seq_strategy,
    block_size=st.sampled_from([4, 8, 16]),
    tile_exp=st.integers(-1, 2),       # tile_n = block_size * 2**exp
    block_q=st.sampled_from([1, 2, 4, 8]),
    qpk=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31),
)
def test_qblock_matches_oracle(seqs, block_size, tile_exp, block_q, qpk, seed):
    tile_n = max(2, int(block_size * 2.0 ** tile_exp))
    cfg = KernelConfig(variant="qblock", block_size=block_size,
                       tile_n=tile_n, block_q=block_q)
    model = model_for(qpk, 2, 16)
    check(make_scenario(seqs, cfg, model, seed=seed))


@settings(**SETTINGS)
@given(
    ctxs=st.lists(st.integers(1, 150), min_size=1, max_size=4),
    block_size=st.sampled_from([4, 8, 16]),
    tile_exp=st.integers(-1, 2),
    num_segments=st.sampled_from([1, 2, 4, 8, 16]),
    qpk=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31),
)
def test_parts_matches_oracle(ctxs, block_size, tile_exp, num_segments,
                              qpk, seed):
    # decode-only: one query token per sequence
    seqs = [(c, 1) for c in ctxs]
    tile_n = max(2, int(block_size * 2.0 ** tile_exp))
    cfg = KernelConfig(variant="parts", block_size=block_size,
                       tile_n=tile_n, block_q=1, num_segments=num_segments)
    model = model_for(qpk, 2, 16)
    check(make_scenario(seqs, cfg, model, seed=seed))


@settings(**SETTINGS)
@given(
    seqs=seq_strategy,
    static_programs=st.sampled_from([1, 2, 4, 16]),
    block_q=st.sampled_from([1, 4]),
    seed=st.integers(0, 2**31),
)
def test_static_matches_oracle(seqs, static_programs, block_q, seed):
    cfg = KernelConfig(variant="static", block_size=8, tile_n=8,
                       block_q=block_q, static_programs=static_programs)
    model = model_for(2, 2, 16)
    check(make_scenario(seqs, cfg, model, seed=seed))


@settings(**SETTINGS)
@given(
    seqs=seq_strategy,
    block_q=st.sampled_from([1, 4]),
    tile_n=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31),
)
def test_flash_matches_oracle(seqs, block_q, tile_n, seed):
    cfg = KernelConfig(variant="flash", block_size=8, tile_n=tile_n,
                       block_q=block_q)
    model = model_for(2, 2, 16)
    check(make_scenario(seqs, cfg, model, seed=seed))


@settings(**SETTINGS)
@given(
    seqs=seq_strategy,
    seed=st.integers(0, 2**31),
)
def test_variant_cross_agreement(seqs, seed):
    """All variants must produce identical outputs on identical inputs —
    the paper's functional bar for swapping kernels via heuristics."""
    model = model_for(2, 2, 16)
    outs = {}
    for variant, extra in [("naive", {}), ("qblock", {}), ("static", {}),
                           ("flash", {})]:
        cfg = KernelConfig(variant=variant, block_size=8, tile_n=8,
                           block_q=1, use_dot=False,
                           static_programs=2, **extra)
        scn = make_scenario(seqs, cfg, model, seed=seed)
        kernel = get_kernel(cfg)
        out = np.asarray(kernel(*scn.operands(), cfg=cfg, model=model,
                                bucket=scn.bucket))
        outs[variant] = out[scn.valid_rows()]
    base = outs.pop("naive")
    for name, o in outs.items():
        np.testing.assert_allclose(o, base, atol=3e-5, rtol=1e-4,
                                   err_msg=f"{name} != naive")
