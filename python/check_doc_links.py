#!/usr/bin/env python3
"""Offline markdown link checker for the docs tree.

Usage: python3 python/check_doc_links.py [DIR ...]

Scans every ``*.md`` under the given directories (default: ``docs`` plus
the repository-root markdown files) for inline links and validates that

* relative links resolve to an existing file or directory (anchors are
  stripped; pure-anchor links are checked against the same file's
  headings),
* absolute ``http(s)`` links are merely recorded, never fetched — CI is
  offline by design.

Exits non-zero listing every broken link. No dependencies beyond the
standard library.
"""
import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def slugify(heading):
    """GitHub-style anchor slug (good enough for our own docs)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def check_file(path):
    broken = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    anchors = {slugify(h) for h in HEADING.findall(text)}
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, anchor = target.partition("#")
        if not base:
            if anchor and anchor not in anchors:
                broken.append((target, "missing heading anchor"))
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), base))
        if not os.path.exists(resolved):
            broken.append((target, f"no such file: {resolved}"))
    return broken


def main(argv):
    roots = argv or ["docs"] + [
        f for f in os.listdir(".") if f.endswith(".md")]
    files = []
    missing_roots = 0
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
            continue
        if not os.path.isdir(root):
            # fail closed: a renamed/deleted explicit root must not turn
            # the guard into a silent no-op
            print(f"check_doc_links: no such file or directory: {root}",
                  file=sys.stderr)
            missing_roots += 1
            continue
        for dirpath, _, names in os.walk(root):
            files.extend(os.path.join(dirpath, n) for n in names
                         if n.endswith(".md"))
    if not files:
        print("check_doc_links: no markdown files found", file=sys.stderr)
        return 1
    failures = missing_roots
    for path in sorted(files):
        for target, why in check_file(path):
            print(f"{path}: broken link '{target}' ({why})",
                  file=sys.stderr)
            failures += 1
    print(f"check_doc_links: {len(files)} files, {failures} broken links"
          + (f", {missing_roots} missing roots" if missing_roots else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
