#!/usr/bin/env python3
"""Counter <-> gate-table drift check.

Usage: python3 python/check_counter_docs.py [BASELINE] [BENCHMARKS_MD]

Asserts that the gate table in ``docs/BENCHMARKS.md`` and the
fingerprint counters of ``BENCH_baseline.json`` name exactly the same
set:

* every counter appearing in any scenario fingerprint of the baseline
  must be listed in the gate table (an undocumented counter has an
  undocumented gate class), and
* every counter the gate table lists must still exist in the baseline
  (a documented counter the code no longer emits is stale docs).

Per-tenant counters are normalized to the spellings the table uses:
``wfq_admitted_tokens:acme`` matches the documented
``wfq_admitted_tokens:<tenant>``, likewise ``shed_by_tenant:<tenant>``.

Exits non-zero listing every drifted name; fails closed when either
input file or the gate table itself is missing. Stdlib only — runs in
the offline CI ``docs`` job and under ``make docs``.
"""
import json
import os
import re
import sys

# per-tenant counter families: one table row spelling covers the whole
# family
TENANT_PREFIXES = ("wfq_admitted_tokens:", "shed_by_tenant:")

GATE_HEADER = re.compile(r"^\|\s*gate\s*\|\s*counters\s*\|", re.IGNORECASE)
BACKTICKED = re.compile(r"`([^`]+)`")


def normalize(counter):
    for prefix in TENANT_PREFIXES:
        if counter.startswith(prefix):
            return prefix + "<tenant>"
    return counter


def baseline_counters(path):
    with open(path, encoding="utf-8") as f:
        report = json.load(f)
    counters = set()
    for scenario in report["scenarios"]:
        counters.update(normalize(k) for k in scenario["fingerprint"])
    return counters


def documented_counters(path):
    """Backticked names from the counters column of the gate table."""
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    rows = []
    in_table = False
    for line in lines:
        if GATE_HEADER.match(line):
            in_table = True
            continue
        if in_table:
            if not line.startswith("|"):
                break
            if re.match(r"^\|[\s|:-]+$", line):  # separator row
                continue
            rows.append(line)
    if not rows:
        return None
    documented = set()
    for row in rows:
        cells = row.split("|")
        if len(cells) < 3:
            continue
        # cells[1] is the gate class, cells[2] the counters column;
        # backticked names in the rationale column are prose, not policy
        documented.update(normalize(c) for c in BACKTICKED.findall(cells[2]))
    return documented


def main(argv):
    baseline = argv[0] if argv else "BENCH_baseline.json"
    benchmarks = argv[1] if len(argv) > 1 else os.path.join(
        "docs", "BENCHMARKS.md")
    failures = 0
    for path in (baseline, benchmarks):
        if not os.path.isfile(path):
            # fail closed: a moved input must not turn the guard into a
            # silent no-op
            print(f"check_counter_docs: no such file: {path}",
                  file=sys.stderr)
            failures += 1
    if failures:
        return 1
    in_baseline = baseline_counters(baseline)
    documented = documented_counters(benchmarks)
    if documented is None:
        print(f"check_counter_docs: no gate table "
              f"('| gate | counters | ...') found in {benchmarks}",
              file=sys.stderr)
        return 1
    for name in sorted(in_baseline - documented):
        print(f"{benchmarks}: counter '{name}' is in {baseline} but "
              f"missing from the gate table", file=sys.stderr)
        failures += 1
    for name in sorted(documented - in_baseline):
        print(f"{benchmarks}: gate table lists '{name}' but no scenario "
              f"in {baseline} produces it", file=sys.stderr)
        failures += 1
    print(f"check_counter_docs: {len(in_baseline)} baseline counters, "
          f"{len(documented)} documented, {failures} drifted")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
